"""§Roofline report generator: reads experiments/dryrun/*.json and emits the
per-(arch × shape × mesh) roofline table (markdown + CSV rows)."""
from __future__ import annotations

import glob
import json
import os

REPO = os.path.join(os.path.dirname(__file__), "..")
DRYRUN = os.path.join(REPO, "experiments", "dryrun")


def load(tag_filter=""):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        r = json.load(open(f))
        if (r.get("tag") or "") != tag_filter:
            continue
        rows.append(r)
    return rows


def markdown_table(rows) -> str:
    out = ["| arch | shape | mesh | GiB/dev | t_compute | t_memory | "
           "t_collective | dominant | useful | roofline frac |",
           "|---|---|---|---:|---:|---:|---:|---|---:|---:|"]
    for r in rows:
        rr = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['memory']['per_device_gib']:.2f} "
            f"| {rr['t_compute_s']:.3f}s | {rr['t_memory_s']:.3f}s "
            f"| {rr['t_collective_s']:.3f}s | {rr['dominant']} "
            f"| {rr['useful_flop_ratio']:.2f} "
            f"| {rr['roofline_fraction']:.3f} |")
    return "\n".join(out)


def run():
    rows = load()
    print(f"# Roofline table: {len(rows)} baseline cells")
    for r in rows:
        rr = r["roofline"]
        print(f"roofline/{r['arch']}__{r['shape']}__{r['mesh']},0.0,"
              f"dom={rr['dominant']};frac={rr['roofline_fraction']:.4f};"
              f"useful={rr['useful_flop_ratio']:.3f};"
              f"gib={r['memory']['per_device_gib']:.2f}")


if __name__ == "__main__":
    run()

"""Shared benchmark helpers: timing, CSV rows, paper constants."""
from __future__ import annotations

import time

import jax
import numpy as np

# Paper reference points (FAMOUS, Alveo U55C @ 400 MHz unless noted)
PAPER_TABLE1 = [
    # (SL, d_model, heads, TS, latency_ms, GOPS)
    (64, 768, 8, 64, 0.94, 328),
    (64, 768, 4, 64, 1.401, 220),
    (64, 768, 2, 64, 2.281, 135),
    (64, 512, 8, 64, 0.597, 184),
    (64, 256, 8, 64, 0.352, 312),   # paper reports higher GOPS at 256
    (128, 768, 8, 64, 2.0, 314),
    (32, 768, 8, 64, 0.534, 285),
    (16, 768, 8, 64, 13.0, 16),     # paper anomaly row (#8)
    (64, 768, 8, 32, 1.155, 267),
    (64, 768, 8, 16, 1.563, 197),
]

PAPER_TABLE2 = [
    # platform, topology (SL, d_model, h), GOP, latency_ms, GOPS
    ("Intel E5 2698v4 CPU", (64, 768, 12), 0.308, 1.1, 280),
    ("NVIDIA V100 GPU", (64, 512, 4), 0.11, 1.5578, 71),
    ("Intel Xeon Gold 5220R CPU", (64, 512, 8), 0.11, 1.96, 56),
    ("NVIDIA P100 GPU", (64, 512, 4), 0.11, 0.496, 221),
    ("FAMOUS U55C (64,768,8)", (64, 768, 8), 0.308, 0.94, 328),
    ("FAMOUS U55C (64,512,8)", (64, 512, 8), 0.11, 0.597, 184),
]

PAPER_TABLE3 = [
    ("A3 (ASIC 40nm, sparse)", 221),
    ("Sanger (ASIC 55nm, sparse)", 529),
    ("SpAtten (ASIC 55nm, sparse)", 360),
    ("SALO (ASIC 45nm, sparse)", 704),
    ("FAMOUS (FPGA U55C, dense)", 328),
]


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time in microseconds of fn(*args) (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")

"""Paper §VII analogue: validate the analytical latency model.

The paper checks its Eq. 3–14 predictions against measured U55C latency
(0.98 ms predicted vs 0.94 ms measured for test #1).  Without a TPU we
validate the two halves the model is built from:

  1. FLOPs/bytes: the model's per-module counts vs the while-aware HLO cost
     of the *actually lowered* MHA block (must agree within ~15%);
  2. trend fidelity: predicted latency is monotone in SL and d_model and
     reproduces the TS trend of Table I tests #9–#10 and the paper's
     prediction ratio (pred/meas = 0.98/0.94 ≈ 1.04) is matched by our
     pred/roofline ratio being within a comparable band.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import analytical, famous
from repro.roofline import hlo_cost


def run():
    print("# Analytical-model validation (paper §VII)")
    B, SL, D, H = 1, 4096, 2048, 16
    dh = D // H
    cfg = famous.FamousConfig(impl="xla", tile_k=512)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, SL, D), jnp.bfloat16)
    ws = [jax.random.normal(k, (D, H, dh), jnp.bfloat16) * 0.05
          for k in ks[1:]]

    def f(x, wq, wk, wv):
        q, k, v = famous.qkv_projection(x, wq, wk, wv, cfg=cfg)
        return famous.attention(q, k, v, causal=True, cfg=cfg)

    compiled = jax.jit(f).lower(x, *ws).compile()
    hc = hlo_cost.analyse_hlo(compiled.as_text())
    lat = analytical.mha_latency(batch=B, seq=SL, heads=H, kv_heads=H,
                                 head_dim=dh, d_model=D, tile_q=512,
                                 tile_k=512, tile_d=512)
    flop_ratio = lat.flops / max(hc.flops, 1)
    common.emit("analytical/flops_model_vs_hlo", 0.0,
                f"model={lat.flops:.3e};hlo={hc.flops:.3e};"
                f"ratio={flop_ratio:.3f}")
    assert 0.85 < flop_ratio < 1.25, flop_ratio

    # trend checks (Table I).  At the paper's own SL=64 the model is
    # latency-bound and tile size barely matters on a TPU (DESIGN.md §2);
    # the TS trend is checked at a TPU-relevant scale.
    t_by_ts = {ts: analytical.mha_latency(
        batch=1, seq=4096, heads=16, kv_heads=16, head_dim=128, d_model=2048,
        tile_q=ts, tile_k=ts, tile_d=ts).total for ts in (128, 256, 512)}
    assert t_by_ts[128] >= t_by_ts[256] >= t_by_ts[512]
    paper_ts_ratio = 1.563 / 0.94          # TS16 vs TS64 on U55C
    ours_ts_ratio = t_by_ts[128] / t_by_ts[512]
    common.emit("analytical/ts_trend", 0.0,
                f"pred_TSx4_ratio={ours_ts_ratio:.2f};"
                f"paper_TSx4_ratio={paper_ts_ratio:.2f}")

    t_by_sl = {sl: analytical.mha_latency(
        batch=1, seq=sl, heads=8, kv_heads=8, head_dim=96, d_model=768,
        tile_q=128, tile_k=128, tile_d=128).total for sl in (32, 64, 128)}
    assert t_by_sl[32] < t_by_sl[64] < t_by_sl[128]
    paper_sl_ratio = 2.0 / 0.534           # SL128 / SL32
    common.emit("analytical/sl_trend", 0.0,
                f"pred_SLx4_ratio={t_by_sl[128]/t_by_sl[32]:.2f};"
                f"paper_SLx4_ratio={paper_sl_ratio:.2f}")


if __name__ == "__main__":
    run()

"""Paper Table II analogue: FAMOUS vs general-purpose baselines.

The paper compares its dense-MHA engine against CPU/GPU at the same
topology.  We reproduce the *structure* of that comparison on this host:
the paper-faithful reference implementation (materialised S — what the
CPU/GPU baselines run) vs the FAMOUS-tiled online-softmax path vs the int8
path, at the paper's topologies, plus the analytical v5e projection next to
the paper's published platform numbers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import analytical, famous


def run():
    print("# Table II analogue: dense-MHA implementations at paper topologies")
    for (name, (SL, D, H), gop, paper_ms, paper_gops) in common.PAPER_TABLE2:
        dh = D // H
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (1, SL, D), jnp.float32)
        ws = [jax.random.normal(k, (D, H, dh), jnp.float32) * 0.05
              for k in ks[1:]]

        rows = {}
        for impl in ("reference", "xla"):
            cfg = famous.FamousConfig(impl=impl, tile_d=64)

            @jax.jit
            def f(x, wq, wk, wv, cfg=cfg):
                q, k, v = famous.qkv_projection(x, wq, wk, wv, cfg=cfg)
                return famous.attention(q, k, v, causal=False, cfg=cfg)

            rows[impl] = common.timeit(f, x, *ws)
        lat8 = analytical.mha_latency(batch=1, seq=SL, heads=H, kv_heads=H,
                                      head_dim=dh, d_model=D, dtype_bytes=1,
                                      tile_q=128, tile_k=128, tile_d=128,
                                      quant="int8")
        common.emit(
            f"table2/{name.replace(' ', '_')}", rows["xla"],
            f"ref_us={rows['reference']:.1f};speedup_vs_ref="
            f"{rows['reference']/rows['xla']:.2f}x;"
            f"pred_v5e_gops={lat8.gops():.0f};paper_ms={paper_ms};"
            f"paper_gops={paper_gops}")

    # at the paper's SL=64 the online-softmax path degenerates to the
    # reference (one key tile); show the tiling win at a TPU-relevant SL too
    SL, D, H = 2048, 768, 8
    dh = D // H
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (1, SL, D), jnp.float32)
    ws = [jax.random.normal(k, (D, H, dh), jnp.float32) * 0.05
          for k in ks[1:]]
    rows = {}
    for impl in ("reference", "xla"):
        cfg = famous.FamousConfig(impl=impl, tile_d=256, tile_k=512)

        @jax.jit
        def f(x, wq, wk, wv, cfg=cfg):
            q, k, v = famous.qkv_projection(x, wq, wk, wv, cfg=cfg)
            return famous.attention(q, k, v, causal=True, cfg=cfg)

        rows[impl] = common.timeit(f, x, *ws)
    common.emit("table2/tiled_vs_materialised_SL2048", rows["xla"],
                f"ref_us={rows['reference']:.1f};speedup="
                f"{rows['reference']/rows['xla']:.2f}x")


if __name__ == "__main__":
    run()

"""Benchmark harness — one module per paper table (+ roofline/kernels).
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (analytical_validation, kernels_bench,
                            roofline_report, serving_bench, table1_sweep,
                            table2_baselines, table34_accelerators)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    mods = {
        "table1": table1_sweep,
        "table2": table2_baselines,
        "table34": table34_accelerators,
        "analytical": analytical_validation,
        "kernels": kernels_bench,
        "serving": serving_bench,
        "roofline": roofline_report,
    }
    for name, mod in mods.items():
        if only and name != only:
            continue
        mod.run()


if __name__ == '__main__':
    main()

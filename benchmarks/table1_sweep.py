"""Paper Table I analogue: runtime-programmable parameter sweep.

Sweeps (heads, d_model, SL) at runtime over ONE set of compiled executables
(the FAMOUS µB story) and TS (= tile sizes) as a "re-synthesis" knob, on the
paper's BERT-variant topology.  For each point we report:
  * measured CPU wall time of the MHA block (relative trends only — this
    container has no TPU),
  * the analytical model's predicted v5e latency (§VII port) and GOPS,
  * the paper's measured U55C latency/GOPS where available.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import analytical, famous


def _mha(B, SL, D, H, dh, impl, tiles=512):
    cfg = famous.FamousConfig(impl=impl, tile_q=tiles, tile_k=tiles,
                              tile_d=tiles)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, SL, D), jnp.float32)
    wq = jax.random.normal(ks[1], (D, H, dh), jnp.float32) * 0.05
    wk = jax.random.normal(ks[2], (D, H, dh), jnp.float32) * 0.05
    wv = jax.random.normal(ks[3], (D, H, dh), jnp.float32) * 0.05

    @jax.jit
    def f(x, wq, wk, wv):
        q, k, v = famous.qkv_projection(x, wq, wk, wv, cfg=cfg)
        return famous.attention(q, k, v, causal=False, cfg=cfg)

    return f, (x, wq, wk, wv)


def run():
    print("# Table I analogue: sweep (h, d_model, SL, TS)")
    print("# paper row: measured U55C ms/GOPS; ours: CPU us (trend) + "
          "analytical v5e us/GOPS")
    for (SL, D, H, TS, paper_ms, paper_gops) in common.PAPER_TABLE1:
        dh = D // H
        f, args = _mha(1, SL, D, H, dh, "xla")
        us = common.timeit(f, *args)
        lat = analytical.mha_latency(batch=1, seq=SL, heads=H, kv_heads=H,
                                     head_dim=dh, d_model=D,
                                     tile_q=max(TS, 128), tile_k=max(TS, 128),
                                     tile_d=max(TS, 128), dtype_bytes=1,
                                     quant="int8")
        gop = analytical.paper_gops(seq=SL, d_model=D, heads=H)
        common.emit(
            f"table1/SL{SL}_d{D}_h{H}_TS{TS}", us,
            f"pred_v5e_us={lat.total*1e6:.1f};pred_gops={lat.gops():.0f};"
            f"paper_ms={paper_ms};paper_gops={paper_gops};gop={gop:.3f}")


if __name__ == "__main__":
    run()

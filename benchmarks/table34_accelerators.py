"""Paper Tables III & IV analogue: accelerator-landscape comparison.

Projects our TPU-v5e implementation (analytical model at the paper's
topology, dense, int8 like the paper's 8-bit fixed point) into the paper's
comparison tables against the published ASIC/FPGA numbers.  Also reports the
FAMOUS kernels' utilization-at-roofline for the same workload, which is the
honest TPU-side quantity comparable to "GOPS at 400 MHz".
"""
from __future__ import annotations

from benchmarks import common
from repro.core import analytical


def run():
    print("# Table III analogue: dense (ours/FAMOUS) vs sparse ASICs")
    lat8 = analytical.mha_latency(batch=1, seq=64, heads=8, kv_heads=8,
                                  head_dim=96, d_model=768, tile_q=128,
                                  tile_k=128, tile_d=128, dtype_bytes=1,
                                  quant="int8")
    ours_gops = lat8.gops()
    for name, gops in common.PAPER_TABLE3:
        common.emit(f"table3/{name.replace(' ', '_')}", 0.0,
                    f"published_gops={gops}")
    common.emit("table3/OURS_tpu-v5e_dense_int8_(64,768,8)", 0.0,
                f"pred_gops={ours_gops:.0f};pred_latency_us="
                f"{lat8.total*1e6:.1f}")
    print("# note: tiny SL=64 batch=1 leaves the MXU latency-bound — the "
          "paper's regime favours small accelerators; at batch 64 the same "
          "kernel projects to:")
    lat_b = analytical.mha_latency(batch=64, seq=64, heads=8, kv_heads=8,
                                   head_dim=96, d_model=768, tile_q=128,
                                   tile_k=128, tile_d=128, dtype_bytes=1,
                                   quant="int8")
    common.emit("table3/OURS_batch64", 0.0,
                f"pred_gops={lat_b.gops():.0f}")


if __name__ == "__main__":
    run()

"""Kernel micro-benchmarks: FAMOUS Pallas kernels (interpret mode — CPU
correctness path) vs their XLA equivalents, plus the analytical VMEM/II
breakdown per module that a real-TPU run would validate."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import analytical, famous


def run():
    print("# kernel-level: XLA path timings (CPU) + per-module analytical "
          "v5e breakdown")
    B, SL, D, H = 1, 2048, 1024, 8
    dh = D // H
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, SL, D), jnp.float32)
    ws = [jax.random.normal(k, (D, H, dh), jnp.float32) * 0.05
          for k in ks[1:]]
    cfg = famous.FamousConfig(impl="xla")

    @jax.jit
    def qkv(x, wq, wk, wv):
        return famous.qkv_projection(x, wq, wk, wv, cfg=cfg)

    us = common.timeit(qkv, x, *ws)
    common.emit("kernels/qkv_xla", us, f"tokens={B*SL}")

    q, k, v = qkv(x, *ws)

    @jax.jit
    def attn(q, k, v):
        return famous.attention(q, k, v, causal=True, cfg=cfg)

    us = common.timeit(attn, q, k, v)
    common.emit("kernels/attention_xla_flash", us, "")

    # ---- fwd+bwd (training path): flash custom-VJP, XLA vs Pallas --------
    # The Pallas path runs in interpret mode off-TPU, so it gets a smaller
    # topology — this benchmarks the kernel *plumbing* (fwd + dq + dk/dv
    # custom VJP) on CPU; a real-TPU run exercises the compiled kernels.
    Bg, Sg, Hg, KVg, dhg = 1, 512, 4, 2, 64
    kg = jax.random.split(jax.random.PRNGKey(1), 4)
    qg = jax.random.normal(kg[0], (Bg, Sg, Hg, dhg), jnp.float32) * 0.5
    kk = jax.random.normal(kg[1], (Bg, Sg, KVg, dhg), jnp.float32) * 0.5
    vg = jax.random.normal(kg[2], (Bg, Sg, KVg, dhg), jnp.float32) * 0.5
    ct = jax.random.normal(kg[3], (Bg, Sg, Hg, dhg), jnp.float32)

    def make_loss(icfg):
        def loss(q, k, v):
            out = famous.attention(q, k, v, causal=True, cfg=icfg)
            return jnp.sum(out * ct)
        return jax.jit(jax.grad(loss, argnums=(0, 1, 2)))

    grad_xla = make_loss(famous.FamousConfig(impl="xla", tile_k=128))
    us = common.timeit(grad_xla, qg, kk, vg)
    common.emit("kernels/attention_fwd_bwd_xla", us,
                f"shape={Bg}x{Sg}x{Hg}x{dhg};gqa={Hg//KVg}")

    grad_pl = make_loss(famous.FamousConfig(impl="pallas", tile_q=128,
                                            tile_k=128))
    us = common.timeit(grad_pl, qg, kk, vg, warmup=1, iters=3)
    common.emit("kernels/attention_fwd_bwd_pallas_interpret", us,
                f"shape={Bg}x{Sg}x{Hg}x{dhg};gqa={Hg//KVg}")

    # ---- decode (serving hot path): contiguous vs paged KV cache ---------
    # Same math, two cache layouts: a per-slot (B, Skv, KV, dh) stripe vs a
    # shared page pool addressed through a scalar-prefetched page table.
    Bd, Hd, KVd, dhd, Skv, ps = 4, 8, 4, 64, 1024, 64
    n_p = Skv // ps
    kd = jax.random.split(jax.random.PRNGKey(2), 4)
    qd = jax.random.normal(kd[0], (Bd, 1, Hd, dhd), jnp.float32)
    kc = jax.random.normal(kd[1], (Bd, Skv, KVd, dhd), jnp.float32)
    vc = jax.random.normal(kd[2], (Bd, Skv, KVd, dhd), jnp.float32)
    lens = jnp.asarray([Skv, Skv // 2, 100, 7], jnp.int32)  # mixed residency
    dcfg = famous.FamousConfig(impl="xla")

    @jax.jit
    def dense_decode(q, k, v, lens):
        return famous.decode_attention(q, k, v, lens, cfg=dcfg)

    us = common.timeit(dense_decode, qd, kc, vc, lens)
    common.emit("kernels/decode_contiguous_xla", us, f"skv={Skv};b={Bd}")

    n_pages = 1 + Bd * n_p
    ids = jnp.arange(1, n_pages).reshape(Bd, n_p).astype(jnp.int32)
    kp = jnp.zeros((n_pages, ps, KVd, dhd), jnp.float32
                   ).at[ids].set(kc.reshape(Bd, n_p, ps, KVd, dhd))
    vp = jnp.zeros((n_pages, ps, KVd, dhd), jnp.float32
                   ).at[ids].set(vc.reshape(Bd, n_p, ps, KVd, dhd))

    @jax.jit
    def paged_decode(q, kp, vp, pt, lens):
        return famous.paged_decode_attention(q, kp, vp, pt, lens, cfg=dcfg)

    us = common.timeit(paged_decode, qd, kp, vp, ids, lens)
    common.emit("kernels/decode_paged_gather_xla", us,
                f"page={ps};pages={n_pages}")

    pcfg = famous.FamousConfig(impl="pallas")

    @jax.jit
    def paged_decode_pl(q, kp, vp, pt, lens):
        return famous.paged_decode_attention(q, kp, vp, pt, lens, cfg=pcfg)

    us = common.timeit(paged_decode_pl, qd, kp, vp, ids, lens,
                       warmup=1, iters=3)
    common.emit("kernels/decode_paged_pallas_interpret", us,
                f"page={ps};pages={n_pages}")

    # ---- int8 quantized pool: same decode, in-kernel dequant -------------
    # The pool shrinks 4x (int8 payload; the per-token-per-head fp32 scale
    # adds 4/dh) — the rows measure what the dequant costs on top of the
    # fp paged path at identical geometry.
    from repro.core import quant as quant_lib
    kq8, kscale = quant_lib.quantize(kp, axis=-1)
    vq8, vscale = quant_lib.quantize(vp, axis=-1)
    kscale, vscale = kscale[..., 0], vscale[..., 0]

    @jax.jit
    def paged_decode_q8(q, kp, vp, ks, vs, pt, lens):
        return famous.paged_decode_attention(q, kp, vp, pt, lens,
                                             k_scale=ks, v_scale=vs,
                                             cfg=dcfg)

    us = common.timeit(paged_decode_q8, qd, kq8, vq8, kscale, vscale,
                       ids, lens)
    fp_bytes = kp.nbytes + vp.nbytes
    q8_bytes = (kq8.nbytes + vq8.nbytes + kscale.astype(jnp.float32).nbytes
                + vscale.astype(jnp.float32).nbytes)
    common.emit("kernels/decode_paged_int8_gather_xla", us,
                f"page={ps};pages={n_pages};"
                f"bytes_vs_fp={q8_bytes/fp_bytes:.3f}")

    @jax.jit
    def paged_decode_q8_pl(q, kp, vp, ks, vs, pt, lens):
        return famous.paged_decode_attention(q, kp, vp, pt, lens,
                                             k_scale=ks, v_scale=vs,
                                             cfg=pcfg)

    us = common.timeit(paged_decode_q8_pl, qd, kq8, vq8, kscale, vscale,
                       ids, lens, warmup=1, iters=3)
    common.emit("kernels/decode_paged_int8_pallas_interpret", us,
                f"page={ps};pages={n_pages}")

    lat = analytical.mha_latency(batch=B, seq=SL, heads=H, kv_heads=H,
                                 head_dim=dh, d_model=D)
    for m in lat.modules:
        common.emit(f"kernels/v5e_pred_{m.name}", m.t_total * 1e6,
                    f"ii_us={m.ii*1e6:.2f};steps={m.steps};"
                    f"vmem_kib={m.vmem_bytes/1024:.0f}")
    tuned = analytical.autotune_tiles(batch=B, seq=SL, heads=H, kv_heads=H,
                                      head_dim=dh, d_model=D)
    common.emit("kernels/v5e_autotuned_total",
                tuned["latency"].total * 1e6, f"tiles={tuned['tiles']}")


if __name__ == "__main__":
    run()

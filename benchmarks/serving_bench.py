"""Serving-throughput microbench: tokens/s through the continuous-batching
engine at mixed request lengths, contiguous vs paged KV cache.

Emits one CSV row per (cache_kind) with tokens/s and the cache HBM footprint
the layout implies — the paged row also runs a half-footprint oversubscribed
pool to show admission control sustaining throughput with less memory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine
from repro.serve.paged import PagedCacheConfig

N_SLOTS, MAX_SEQ, PAGE = 4, 256, 16
MAX_NEW = 16


def _requests(cfg, n=16, seed=0):
    rng = np.random.default_rng(seed)
    # bimodal mix: mostly short prompts plus a few long-context stragglers
    lens = [int(rng.integers(4, 24)) if i % 4 else int(rng.integers(96, 160))
            for i in range(n)]
    return [Request(rid=i,
                    tokens=list(rng.integers(0, cfg.vocab_size, size=n_)),
                    max_new=MAX_NEW)
            for i, n_ in enumerate(lens)]


def _cache_bytes(engine) -> int:
    return sum(b.size * b.dtype.itemsize
               for b in jax.tree_util.tree_leaves(engine.caches))


def _bench(params, cfg, label, **kw):
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=N_SLOTS, max_seq=MAX_SEQ, **kw)
    reqs = _requests(cfg)
    engine.run(_requests(cfg, n=N_SLOTS, seed=1), max_steps=40)  # warm jits
    t0 = time.monotonic()
    done = engine.run(reqs)
    dt = time.monotonic() - t0
    tok = sum(len(r.out) for r in done)
    us_per_tok = dt / max(tok, 1) * 1e6
    common.emit(f"serving/{label}", us_per_tok,
                f"tok_s={tok/dt:.1f};requests={len(done)};"
                f"cache_mib={_cache_bytes(engine)/2**20:.2f}")


def run():
    print("# serving-level: continuous batching tokens/s at mixed request "
          "lengths (CPU), contiguous vs paged KV cache")
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    _bench(params, cfg, "contiguous")
    _bench(params, cfg, "paged", cache_kind="paged", page_size=PAGE)
    half = max(2, PagedCacheConfig.default_pool(N_SLOTS, MAX_SEQ, PAGE) // 2)
    _bench(params, cfg, "paged_oversubscribed_half_pool",
           cache_kind="paged", page_size=PAGE, n_pages=half)


if __name__ == "__main__":
    run()

"""Serving-level microbench: monolithic vs chunked prefill under a mixed
long/short workload, contiguous vs paged KV cache, and cold-vs-warm
prefix caching under a repeated-prefix workload.

Beyond raw tokens/s, each row reports request-level latency percentiles —
the numbers the Scheduler/Runtime split actually moves:

  * **TTFT** (time to first token, p50/p95): monolithic prefill stalls
    every decode slot while a long prompt prefills head-of-line; chunked
    prefill bounds the stall to one budget-sized chunk per step; a warm
    prefix cache skips the shared head's chunks entirely.
  * **TPOT** (time per output token after the first, p50/p95): how steady
    decode remains while prompts are being prefilled in between.

The ``prefix_cold`` / ``prefix_warm`` rows serve the same shared-system-
prompt workload twice through one prefix-cached engine; the warm row also
reports ``pages_saved`` (pages aliased instead of allocated+prefilled) and
asserts warm outputs are token-identical to cold with executables still
O(1) — the acceptance gate for the prefix cache.

Set ``SERVING_BENCH_TINY=1`` for the CI smoke configuration (small model,
few requests) — scripts/ci.sh runs it so scheduler and prefix-cache
regressions fail CI.
"""
from __future__ import annotations

import contextlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.analysis import retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.obs.metrics import Histogram, validate_prometheus_text
from repro.obs.runtime import Observer
from repro.obs.trace import now
from repro.serve.engine import Request, ServingEngine
from repro.serve.paged import PagedCacheConfig

TINY = bool(int(os.environ.get("SERVING_BENCH_TINY", "0")))
# run ONLY the mesh (tp1/tp2/tp4) leg — the ci.sh multi-device stage sets
# this so the sharded rows run in their own forced-8-device process while
# the main TINY bench keeps its 1-device view (see tests/conftest.py)
MESH_ONLY = bool(int(os.environ.get("SERVING_BENCH_MESH_ONLY", "0")))
N_SLOTS = 4
MAX_SEQ = 64 if TINY else 256
PAGE = 16
CHUNK = 16 if TINY else 32
MAX_NEW = 4 if TINY else 16
N_REQ = 6 if TINY else 16
# the speculation rows decode longer: acceptance comes from the drafter
# mining the *generated* history's cycles, which max_new=4 never builds
SPEC_NEW = 16 if TINY else 48
# the kv_int8 rows decode longer still: the quantized pool's memory win
# only shows when live KV (not prefill throughput) is the bottleneck
KV_NEW = 40 if TINY else 160
# documented accuracy gate for kv_int8: max |Δlogit| between the fp and
# int8 decode paths on the trained bench model (measured ~0.27; the bound
# leaves ~2x headroom).  Greedy parity additionally requires the model's
# top-2 logit margins to exceed this drift — true for the trained chain
# model below, NOT for random-init weights, whose near-tie margins flip
# under any lossy cache (see docs/serving.md "Quantized KV").
KV_INT8_LOGIT_BOUND = 0.5


def _requests(cfg, n=N_REQ, seed=0):
    rng = np.random.default_rng(seed)
    # bimodal mix: mostly short prompts plus a few long-context stragglers
    long_lo, long_hi = (MAX_SEQ // 2, MAX_SEQ - MAX_NEW - 1)
    lens = [int(rng.integers(4, 24)) if i % 4
            else int(rng.integers(long_lo, long_hi)) for i in range(n)]
    return [Request(rid=i,
                    tokens=list(rng.integers(0, cfg.vocab_size, size=n_)),
                    max_new=MAX_NEW)
            for i, n_ in enumerate(lens)]


def _prefix_requests(cfg, n=N_REQ, seed=7, rid0=0):
    """The prefix-cache workload: every prompt = one shared 'system prompt'
    (3/4 of usable context) + a short distinct tail."""
    rng = np.random.default_rng(seed)
    head = (MAX_SEQ - MAX_NEW - 8) * 3 // 4
    shared = list(rng.integers(0, cfg.vocab_size, size=head))
    return [Request(rid=rid0 + i,
                    tokens=shared + list(rng.integers(0, cfg.vocab_size,
                                                      size=rng.integers(2, 8))),
                    max_new=MAX_NEW)
            for i in range(n)]


def _cache_bytes(engine) -> int:
    return sum(b.size * b.dtype.itemsize
               for b in jax.tree_util.tree_leaves(engine.caches))


def _pct(xs, q):
    """Latency percentile through the SHARED histogram quantile path
    (repro.obs.metrics) — the bench reports the same numbers a live
    Prometheus ``histogram_quantile`` over the Observer's TTFT/TPOT
    histograms would, bucket quantization included (~12% resolution at
    the default 20-buckets-per-decade schema)."""
    return Histogram.of(xs).percentile(q) if xs else float("nan")


def _timed_run(engine, reqs, label):
    t0 = now()
    done = engine.run(reqs)
    dt = now() - t0
    served = [r for r in done if r.error is None and r.t_first is not None]
    tok = sum(len(r.out) for r in served)
    ttft = [(r.t_first - r.t_submit) * 1e3 for r in served]
    tpot = [(r.t_done - r.t_first) / max(len(r.out) - 1, 1) * 1e3
            for r in served]
    us_per_tok = dt / max(tok, 1) * 1e6
    common.emit(
        f"serving/{label}", us_per_tok,
        f"tok_s={tok/dt:.1f};requests={len(done)};"
        f"ttft_p50_ms={_pct(ttft, 50):.1f};ttft_p95_ms={_pct(ttft, 95):.1f};"
        f"tpot_p50_ms={_pct(tpot, 50):.1f};tpot_p95_ms={_pct(tpot, 95):.1f};"
        f"prefill_execs={engine.prefill_compilations};"
        f"cache_mib={_cache_bytes(engine)/2**20:.2f}")
    return done, ttft


def _bench(params, cfg, label, **kw):
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=N_SLOTS, max_seq=MAX_SEQ, **kw)
    # warm THIS engine's executables (jit caches are per-instance) with the
    # same length mix as the timed run, so the timed region measures
    # scheduling, not XLA compiles — monolithic mode compiles its whole
    # bucket family here, chunked its two executables (the executable
    # counts in the emitted row keep that asymmetry visible)
    engine.run(_requests(cfg))
    _timed_run(engine, _requests(cfg), label)
    return engine


def _bench_prefix(params, cfg):
    """Cold vs warm rows through ONE prefix-cached engine: run 1 publishes
    the shared head's blocks, run 2 aliases them.  Asserts the acceptance
    gate: warm token-identical to cold, pages saved, executables O(1)."""
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=N_SLOTS, max_seq=MAX_SEQ,
                           prefill_mode="chunked", chunk=CHUNK,
                           cache_kind="paged", page_size=PAGE,
                           prefix_cache=True)
    # executable warmup with an unrelated prompt mix (different seed, so no
    # hash collisions with the timed workload: the cold row stays cold)
    engine.run(_requests(cfg, seed=99))
    hit0 = engine.prefix_hit_pages
    # both timed rows run on the warm engine: zero new executables allowed
    with retrace_guard(engine, label="prefix cold+warm timed runs"):
        cold, cold_ttft = _timed_run(engine, _prefix_requests(cfg),
                                     "prefix_cold")
        hit1 = engine.prefix_hit_pages  # late cold admissions may already hit
        warm, warm_ttft = _timed_run(engine,
                                     _prefix_requests(cfg, rid0=N_REQ),
                                     "prefix_warm")
    saved = engine.prefix_hit_pages - hit1
    common.emit("serving/prefix_warm_vs_cold",
                _pct(warm_ttft, 50) * 1e3,  # us, for the us-valued column
                f"ttft_p50_cold_ms={_pct(cold_ttft, 50):.1f};"
                f"ttft_p50_warm_ms={_pct(warm_ttft, 50):.1f};"
                f"pages_saved_cold={hit1 - hit0};pages_saved_warm={saved};"
                f"cached_free_pages={engine.alloc.cached_free_pages}")
    outs = [r.out for r in sorted(cold, key=lambda r: r.rid)]
    wout = [r.out for r in sorted(warm, key=lambda r: r.rid)]
    assert outs == wout, "warm prefix-cache outputs must be token-identical"
    assert saved > 0, "warm run must alias cached pages"
    assert _pct(warm_ttft, 50) < _pct(cold_ttft, 50), \
        (f"warm TTFT p50 {_pct(warm_ttft, 50):.1f}ms not below cold "
         f"{_pct(cold_ttft, 50):.1f}ms")


def _spec_requests(cfg, kind, n=N_REQ, seed=3, rid0=0):
    """Speculation workloads.  "repetitive": periodic prompts + greedy —
    the prompt-lookup drafter's best case (the continuation keeps citing
    the prompt's own n-grams).  "adversarial": uniform-random prompts +
    temperature-1 sampling — drafts almost never survive, so every step
    pays the verify width for ~1 accepted token (the worst case the
    regression bound guards)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        n_p = int(rng.integers(6, min(16, MAX_SEQ - SPEC_NEW)))
        if kind == "repetitive":
            motif = list(map(int, rng.integers(0, cfg.vocab_size, 4)))
            toks, kw = (motif * MAX_SEQ)[:n_p], {}
        else:
            toks = list(map(int, rng.integers(0, cfg.vocab_size, n_p)))
            kw = dict(temperature=1.0, seed=rid0 + i)
        reqs.append(Request(rid=rid0 + i, tokens=toks, max_new=SPEC_NEW, **kw))
    return reqs


def _bench_spec(params, cfg):
    """spec_off vs spec_on rows, interleaved best-of-N so scheduler noise
    and one-off compiles cancel.  Gates: drafts actually get accepted and
    tok/s wins on the repetitive workload; the adversarial (near-zero
    acceptance) workload stays within a bounded slowdown of plain decode."""
    rounds = 2 if TINY else 3
    engines = {}
    for label, spec in (("spec_off", False), ("spec_on", True)):
        eng = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                            n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK,
                            speculative=spec, draft_k=4)
        eng.run(_spec_requests(cfg, "repetitive", seed=99, rid0=9000))
        engines[label] = eng
    spec_eng = engines["spec_on"]
    tok_s = {}
    for wl in ("repetitive", "adversarial"):
        drafted0, accepted0 = spec_eng.spec_drafted, spec_eng.spec_accepted
        steps0 = spec_eng.spec_steps
        best = {"spec_off": 0.0, "spec_on": 0.0}
        outs = {}
        for rnd in range(rounds):
            for label, eng in engines.items():
                reqs = _spec_requests(cfg, wl, seed=50 + rnd,
                                      rid0=1000 * rnd)
                t0 = now()
                done = eng.run(reqs)
                dt = now() - t0
                assert all(r.error is None for r in done)
                tok = sum(len(r.out) for r in done)
                best[label] = max(best[label], tok / dt)
                outs.setdefault(rnd, {})[label] = \
                    [r.out for r in sorted(done, key=lambda r: r.rid)]
            # interleaved rounds double as a parity check
            assert outs[rnd]["spec_on"] == outs[rnd]["spec_off"], \
                f"speculative {wl} outputs diverged from plain decode"
        drafted = spec_eng.spec_drafted - drafted0
        accepted = spec_eng.spec_accepted - accepted0
        steps = spec_eng.spec_steps - steps0
        acc = accepted / max(drafted, 1)
        per_step = (steps + accepted) / max(steps, 1)
        for label in ("spec_off", "spec_on"):
            meta = f"tok_s={best[label]:.1f};rounds={rounds}"
            if label == "spec_on":
                meta += (f";acceptance={acc:.3f};"
                         f"accepted_per_step={per_step:.2f};"
                         f"drafted={drafted};accepted={accepted}")
            common.emit(f"serving/{label}_{wl}", 1e6 / max(best[label], 1e-9),
                        meta)
        tok_s[wl] = (best["spec_off"], best["spec_on"], acc)
    off, on, acc = tok_s["repetitive"]
    assert acc > 0, "repetitive workload must accept draft tokens"
    assert on > off, \
        f"speculative tok/s {on:.1f} must beat plain {off:.1f} on the " \
        f"repetitive workload"
    off, on, _ = tok_s["adversarial"]
    assert on > 0.4 * off, \
        f"adversarial speculative tok/s {on:.1f} fell below 0.4x plain " \
        f"{off:.1f} — rejected-draft overhead is unbounded"


def _kv_requests(cfg, n, seed):
    """Decode-heavy workload for the kv_int8 rows: short prompts, long
    generations — live KV pages, not prefill, bound throughput."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=list(rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(4, 12)))),
                    max_new=KV_NEW)
            for i in range(n)]


def _train_chain(params, cfg, fcfg):
    """Teach the bench model a deterministic 32-token successor cycle
    (SGD, ~2.5s on CPU) and return (trained_params, chain).

    Greedy-parity gates need a model whose top-1 logit margin exceeds the
    int8 drift; random-init logits are near-ties (~0.01) that argmax-flip
    under ANY lossy cache, so parity is checked on this trained model and
    its in-distribution chain prompts instead."""
    rng = np.random.default_rng(5)
    sub = np.sort(rng.choice(cfg.vocab_size, 32, replace=False))
    cyc = rng.permutation(32)
    succ = {int(sub[cyc[i]]): int(sub[cyc[(i + 1) % 32]]) for i in range(32)}
    chain = [int(sub[cyc[0]])]
    while len(chain) < 512 + 33:
        chain.append(succ[chain[-1]])
    chain = np.array(chain, np.int32)

    @jax.jit
    def sgd_step(p, batch):
        def loss_fn(p):
            logits = transformer.forward(p, batch[:, :-1], cfg, fcfg)
            lp = jax.nn.log_softmax(logits, -1)
            return -jnp.take_along_axis(lp, batch[:, 1:, None], -1).mean()

        loss, g = jax.value_and_grad(loss_fn)(p)
        return jax.tree_util.tree_map(lambda a, b: a - 1.0 * b, p, g), loss

    tparams = params
    for _ in range(120):
        offs = rng.integers(0, 512, size=8)
        batch = jnp.asarray(np.stack([chain[o:o + 33] for o in offs]))
        tparams, loss = sgd_step(tparams, batch)
    assert float(loss) < 0.2, f"chain model failed to train: loss {loss}"
    return tparams, chain


def _chain_requests(chain, n, seed, max_new=8):
    """Prompts cut from the trained chain; prompt+max_new stays inside the
    64 positions the model was trained to generalize over."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        length = int(rng.integers(4, 49))
        off = int(rng.integers(0, 400))
        reqs.append(Request(rid=i,
                            tokens=[int(t) for t in chain[off:off + length]],
                            max_new=max_new))
    return reqs


def _kv_probe(tparams, cfg, fcfg, prompt, max_new, kv_dtype,
              max_seq=64, page=16, chunk=16):
    """Greedy-decode a prompt through the raw paged prefill/decode path and
    return (tokens, per-step logits) — the drift probe the bound gates."""
    n_p = max_seq // page
    pt = jnp.arange(1, n_p + 1, dtype=jnp.int32)[None]
    caches = transformer.make_caches(cfg, 1, max_seq, jnp.float32,
                                     cache_kind="paged", page_size=page,
                                     n_pages=n_p + 1, kv_dtype=kv_dtype)
    n_ctx = len(prompt) - 1
    padded = prompt[:-1] + [0] * (-n_ctx % chunk)
    for off in range(0, len(padded), chunk):
        caches = transformer.prefill_chunk(
            tparams, jnp.asarray([padded[off:off + chunk]], jnp.int32),
            caches, 0, off, min(chunk, n_ctx - off), cfg, fcfg,
            page_table=pt)
    tok, cache_len = prompt[-1], jnp.asarray([n_ctx], jnp.int32)
    toks, logs = [], []
    for _ in range(max_new):
        logits, caches = transformer.decode_step(
            tparams, jnp.asarray([tok], jnp.int32), caches, cache_len,
            cfg, fcfg, page_table=pt)
        logs.append(np.asarray(logits[0], np.float32))
        tok = int(jnp.argmax(logits[0]))
        toks.append(tok)
        cache_len = cache_len + 1
    return toks, np.stack(logs)


def _bench_kv_int8(params, cfg):
    """kv_fp vs kv_int8 rows.  Two legs:

    1. Throughput/capacity at an EQUAL byte budget: the fp pool gets
       pages for ~2 concurrent requests, the int8 pool the same bytes
       (~3.2x the pages at dh=16), and both serve the decode-heavy
       workload interleaved best-of-N.  Gates: bytes-per-token <= 0.55x
       fp, served requests >= fp, tok/s >= fp (fp thrashes preempting,
       int8 keeps all slots resident).
    2. Accuracy on the trained chain model: greedy tokens identical
       between fp and int8 engines, and max |Δlogit| on the raw decode
       path under KV_INT8_LOGIT_BOUND."""
    fcfg = FamousConfig(impl="xla")
    dh = cfg.head_dim
    # int8 payload + one fp32 scale per token-per-kv-head, vs fp32 payload
    bytes_ratio = (dh + 4) / (4 * dh)
    pages_per_req = -(-(12 + KV_NEW) // PAGE)
    fp_pages = 2 * pages_per_req + 1  # null page + ~2 resident requests
    q8_pages = 1 + int((fp_pages - 1) / bytes_ratio)  # same HBM bytes
    n_req, rounds = 8, (2 if TINY else 3)
    engines = {}
    for label, kvd, n_pages in (("kv_fp", "fp", fp_pages),
                                ("kv_int8", "int8", q8_pages)):
        eng = ServingEngine(params, cfg, fcfg, n_slots=N_SLOTS,
                            max_seq=MAX_SEQ, prefill_mode="chunked",
                            chunk=CHUNK, cache_kind="paged", page_size=PAGE,
                            n_pages=n_pages, kv_dtype=kvd)
        eng.run(_kv_requests(cfg, n_req, seed=99))  # warm executables
        engines[label] = eng
    best = {"kv_fp": 0.0, "kv_int8": 0.0}
    served = {"kv_fp": 0, "kv_int8": 0}
    preempt = {"kv_fp": 0, "kv_int8": 0}
    with retrace_guard(engines["kv_fp"], label="kv_fp timed runs"), \
         retrace_guard(engines["kv_int8"], label="kv_int8 timed runs"):
        for rnd in range(rounds):
            for label, eng in engines.items():
                reqs = _kv_requests(cfg, n_req, seed=50 + rnd)
                t0 = now()
                done = eng.run(reqs)
                dt = now() - t0
                ok = [r for r in done if r.error is None]
                best[label] = max(best[label],
                                  sum(len(r.out) for r in ok) / dt)
                served[label] += len(ok)
                preempt[label] += sum(
                    eng.sched.fairness(r.rid).get("preemptions", 0)
                    for r in done)
    bpt = {label: _cache_bytes(eng) / (eng.pcfg.n_pages * PAGE)
           for label, eng in engines.items()}
    for label, eng in engines.items():
        common.emit(
            f"serving/{label}", 1e6 / max(best[label], 1e-9),
            f"tok_s={best[label]:.1f};served={served[label]};"
            f"preemptions={preempt[label]};n_pages={eng.pcfg.n_pages};"
            f"bytes_per_tok={bpt[label]:.0f};rounds={rounds};"
            f"cache_mib={_cache_bytes(eng)/2**20:.2f}")
    ratio = bpt["kv_int8"] / bpt["kv_fp"]
    assert ratio <= 0.55, \
        f"int8 bytes-per-token ratio {ratio:.3f} above the 0.55 gate"
    assert served["kv_int8"] >= served["kv_fp"], \
        f"int8 served {served['kv_int8']} < fp {served['kv_fp']} " \
        f"at the same byte budget"
    assert best["kv_int8"] >= best["kv_fp"], \
        f"int8 tok/s {best['kv_int8']:.1f} below fp " \
        f"{best['kv_fp']:.1f} at the same byte budget"

    # --- accuracy leg: greedy parity + bounded logit drift ---
    tparams, chain = _train_chain(params, cfg, fcfg)
    peng = {}
    for kvd in ("fp", "int8"):
        eng = ServingEngine(tparams, cfg, fcfg, n_slots=N_SLOTS, max_seq=64,
                            prefill_mode="chunked", chunk=16,
                            cache_kind="paged", page_size=16, kv_dtype=kvd)
        eng.run(_chain_requests(chain, 4, seed=98))
        peng[kvd] = eng
    outs = {}
    for kvd, eng in peng.items():
        done = eng.run(_chain_requests(chain, 6 if TINY else 12, seed=11))
        assert all(r.error is None for r in done)
        outs[kvd] = [r.out for r in sorted(done, key=lambda r: r.rid)]
    assert outs["fp"] == outs["int8"], \
        "int8 greedy tokens diverged from fp on the trained bench workload"
    drift = 0.0
    for req in _chain_requests(chain, 3, seed=12):
        toks_f, logits_f = _kv_probe(tparams, cfg, fcfg, req.tokens, 8, "fp")
        toks_q, logits_q = _kv_probe(tparams, cfg, fcfg, req.tokens, 8,
                                     "int8")
        assert toks_f == toks_q
        drift = max(drift, float(np.abs(logits_f - logits_q).max()))
    assert drift <= KV_INT8_LOGIT_BOUND, \
        f"int8 logit drift {drift:.3f} above bound {KV_INT8_LOGIT_BOUND}"
    common.emit("serving/kv_int8_parity", drift * 1e6,
                f"max_dlogit={drift:.4f};bound={KV_INT8_LOGIT_BOUND};"
                f"greedy_identical=1;"
                f"parity_requests={len(outs['fp'])}")


def _bench_obs(params, cfg):
    """``obs_off`` vs ``obs_on`` rows: two otherwise-identical paged
    engines, one carrying a full Observer (metrics + tracing), served
    interleaved best-of-N.  Gates the observability overhead contract
    (docs/observability.md): observer-on outputs token-identical to off,
    and tok/s within 5% (measured ≤2%; the CI gate leaves noise
    headroom).  The exposition the Observer produced is also pushed
    through the format checker so a malformed dump fails the bench, not
    just the unit tests."""
    rounds = 4 if TINY else 3
    obs = Observer(trace=True)
    engines = {}
    for label, o in (("obs_off", None), ("obs_on", obs)):
        eng = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                            n_slots=N_SLOTS, max_seq=MAX_SEQ,
                            prefill_mode="chunked", chunk=CHUNK,
                            cache_kind="paged", page_size=PAGE, observer=o)
        eng.run(_requests(cfg, seed=99))            # warm the executables
        engines[label] = eng

    # decode-heavy workload: short prompts, SPEC_NEW-long generations, so
    # each timed run is long enough that host noise doesn't swamp the
    # ~1-2% hook cost the gate is after
    def _obs_requests(seed):
        rng = np.random.default_rng(seed)
        return [Request(rid=i,
                        tokens=list(rng.integers(0, cfg.vocab_size,
                                                 size=int(rng.integers(4, 14)))),
                        max_new=SPEC_NEW)
                for i in range(N_REQ)]

    best = {"obs_off": 0.0, "obs_on": 0.0}
    best_ratio = 0.0
    with retrace_guard(engines["obs_off"], engines["obs_on"],
                       label="obs_off/obs_on timed runs"):
        for rnd in range(rounds):
            outs, rate = {}, {}
            # alternate which engine goes first so slow drift (thermal,
            # co-tenant load) cancels out of the per-round ratio
            order = ("obs_off", "obs_on") if rnd % 2 == 0 \
                else ("obs_on", "obs_off")
            for label in order:
                reqs = _obs_requests(60 + rnd)
                t0 = now()
                done = engines[label].run(reqs)
                dt = now() - t0
                rate[label] = sum(len(r.out) for r in done) / dt
                best[label] = max(best[label], rate[label])
                outs[label] = [r.out for r in sorted(done,
                                                     key=lambda r: r.rid)]
            assert outs["obs_on"] == outs["obs_off"], \
                "observer-on outputs must be token-identical to observer-off"
            best_ratio = max(best_ratio, rate["obs_on"] / rate["obs_off"])
    snap = obs.snapshot()
    n_samples = validate_prometheus_text(obs.prometheus_text())
    for label in ("obs_off", "obs_on"):
        meta = f"tok_s={best[label]:.1f};rounds={rounds}"
        if label == "obs_on":
            meta += (f";best_on_off_ratio={best_ratio:.3f};"
                     f"trace_events={len(obs.tracer.events)};"
                     f"exposition_samples={n_samples};"
                     f"tokens_counted="
                     f"{snap.get('repro_tokens_generated_total', 0):.0f}")
        common.emit(f"serving/{label}", 1e6 / max(best[label], 1e-9), meta)
    assert obs.tracer.balanced and obs.tracer.events, \
        "observer trace must record balanced, non-empty phase spans"
    # overhead gate: within any single round (temporally adjacent runs of
    # the same workload) the observed engine must reach 95% of the bare
    # engine's throughput at least once — measured cost is ~1-2%, the
    # headroom is CPU-timer noise (docs/observability.md)
    assert best_ratio >= 0.95, \
        f"observer overhead gate: best obs_on/obs_off ratio " \
        f"{best_ratio:.3f} below 0.95 " \
        f"(best tok/s on={best['obs_on']:.1f} off={best['obs_off']:.1f})"


def _bench_mesh():
    """Interleaved ``tp1``/``tp2``/``tp4`` rows on a paged engine over the
    forced-host-device mesh.  Gates: outputs token-identical across TP,
    per-device KV bytes exactly 1/TP of unsharded (the bench config's 4 kv
    heads divide every TP), census O(1) under retrace_guard.  Skips (with a
    note) when fewer than 4 devices are visible — scripts/ci.sh runs this
    leg under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    if jax.device_count() < 4:
        print("# serving/mesh: SKIPPED — needs >= 4 devices (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8)")
        return
    from repro.launch.mesh import make_serving_mesh
    cfg = shrink(get_config("qwen2-7b"), num_heads=8, num_kv_heads=4,
                 head_dim=8)
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    tps, rounds = (1, 2, 4), (2 if TINY else 3)
    engines = {}
    for tp in tps:
        eng = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                            n_slots=N_SLOTS, max_seq=MAX_SEQ, chunk=CHUNK,
                            cache_kind="paged", page_size=PAGE,
                            mesh=make_serving_mesh(tp=tp) if tp > 1 else None)
        eng.run(_requests(cfg, seed=99))            # warm the executables
        engines[tp] = eng
    best = {tp: 0.0 for tp in tps}
    ttft = {tp: [] for tp in tps}
    tpot = {tp: [] for tp in tps}
    with contextlib.ExitStack() as stack:
        for tp in tps:
            stack.enter_context(retrace_guard(engines[tp],
                                              label=f"tp{tp} timed runs"))
        for rnd in range(rounds):
            outs = {}
            for tp in tps:
                reqs = _requests(cfg, seed=50 + rnd)
                t0 = now()
                done = engines[tp].run(reqs)
                dt = now() - t0
                ok = [r for r in done
                      if r.error is None and r.t_first is not None]
                best[tp] = max(best[tp], sum(len(r.out) for r in ok) / dt)
                ttft[tp] += [(r.t_first - r.t_submit) * 1e3 for r in ok]
                tpot[tp] += [(r.t_done - r.t_first) / max(len(r.out) - 1, 1)
                             * 1e3 for r in ok]
                outs[tp] = [r.out for r in sorted(done, key=lambda r: r.rid)]
            assert outs[2] == outs[1] and outs[4] == outs[1], \
                "sharded outputs diverged from the unsharded engine"
    kvb = {tp: engines[tp].cache_bytes_per_device() for tp in tps}
    for tp in tps:
        common.emit(
            f"serving/tp{tp}", 1e6 / max(best[tp], 1e-9),
            f"tok_s={best[tp]:.1f};"
            f"ttft_p50_ms={_pct(ttft[tp], 50):.1f};"
            f"ttft_p95_ms={_pct(ttft[tp], 95):.1f};"
            f"tpot_p50_ms={_pct(tpot[tp], 50):.1f};"
            f"tpot_p95_ms={_pct(tpot[tp], 95):.1f};"
            f"kv_bytes_per_device={kvb[tp]};rounds={rounds}")
    assert kvb[2] * 2 == kvb[1] and kvb[4] * 4 == kvb[1], \
        f"per-device KV bytes must shrink 1/TP, got {kvb}"
    for tp in tps:
        c = engines[tp].compilations
        assert c["prefill"] == 1 and c["decode"] == 1, (tp, c)


def run():
    if MESH_ONLY:
        print("# serving-level: mesh-sharded (tp1/tp2/tp4) leg only")
        _bench_mesh()
        return
    print("# serving-level: continuous batching under a mixed long/short "
          "workload (CPU) — monolithic vs chunked prefill, contiguous vs "
          "paged KV cache, cold vs warm prefix cache; TTFT/TPOT in ms")
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    _bench(params, cfg, "monolithic", prefill_mode="monolithic")
    eng = _bench(params, cfg, "chunked", prefill_mode="chunked", chunk=CHUNK)
    assert eng.prefill_compilations == 1, eng.compilations  # CI tripwire
    _bench(params, cfg, "chunked_paged", prefill_mode="chunked", chunk=CHUNK,
           cache_kind="paged", page_size=PAGE)
    _bench_prefix(params, cfg)
    _bench_obs(params, cfg)
    _bench_spec(params, cfg)
    _bench_kv_int8(params, cfg)
    _bench_mesh()   # prints a skip note on a 1-device host
    if not TINY:
        half = max(2, PagedCacheConfig.default_pool(N_SLOTS, MAX_SEQ,
                                                    PAGE) // 2)
        _bench(params, cfg, "chunked_paged_oversubscribed_half_pool",
               prefill_mode="chunked", chunk=CHUNK, cache_kind="paged",
               page_size=PAGE, n_pages=half)


if __name__ == "__main__":
    run()

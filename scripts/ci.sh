#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): the full pytest suite from the repo root,
# plus a quickstart smoke-run and an intra-repo doc-link check.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# --- doc links: every relative markdown link target must exist -------------
echo "== doc-link check =="
fail=0
for doc in README.md docs/*.md; do
  [ -f "$doc" ] || continue
  base=$(dirname "$doc")
  # extract (path) targets of markdown links; keep repo-relative ones only,
  # stripping any #fragment so anchored links are checked too
  for target in $(grep -o '](\([^)]*\))' "$doc" | sed 's/](//; s/)$//' \
                   | grep -v '^https\?://' | grep -v '^mailto:'); do
    case "$target" in *'"'*) continue ;; esac   # titled-link fragments
    path="${target%%#*}"
    [ -z "$path" ] && continue
    if [ ! -e "$base/$path" ] && [ ! -e "$path" ]; then
      echo "BROKEN LINK: $doc -> $target"
      fail=1
    fi
  done
done
[ "$fail" -eq 0 ] || { echo "doc-link check failed"; exit 1; }
echo "doc links ok"

# --- static analysis: lint vs baseline, Pallas contract check against live
# kernel launches, retrace guard on a warmed engine (repro.analysis) --------
echo "== static analysis =="
python -m repro.analysis

# --- quickstart smoke: the three impls must still agree --------------------
echo "== examples/quickstart.py smoke =="
python examples/quickstart.py

# --- serving bench smoke: scheduler / chunked-prefill / prefix-cache
# regressions fail here (the prefix rows assert warm==cold token parity,
# pages actually saved, and the O(1)-executable census) ---------------------
echo "== benchmarks/serving_bench.py smoke (tiny config) =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" SERVING_BENCH_TINY=1 \
  python benchmarks/serving_bench.py

# --- observability: launcher smoke with metrics + tracing ------------------
# Serve a small batch with the Observer attached, then validate both
# export formats: the Chrome trace must hold >0 balanced events with
# slot/rid attribution, and the Prometheus exposition must pass the
# format checker (docs/observability.md).  The tok/s overhead gate lives
# in the serving bench's obs_off/obs_on rows above.
echo "== observability =="
OBS_TMP=$(mktemp -d)
python -m repro.launch.serve --arch qwen2-7b --requests 4 --slots 2 \
  --max-new 4 --cache-kind paged --prefix-cache \
  --metrics-out "$OBS_TMP/metrics.prom" --trace-out "$OBS_TMP/trace.json"
python - "$OBS_TMP" <<'EOF'
import json, sys
from repro.obs.metrics import validate_prometheus_text
tmp = sys.argv[1]
doc = json.load(open(f"{tmp}/trace.json"))
evs = doc["traceEvents"]
assert evs, "trace must record events"
depth = 0
for e in evs:
    assert {"name", "ph", "ts", "pid", "tid"} <= set(e), e
    depth += {"B": 1, "E": -1}.get(e["ph"], 0)
    assert depth >= 0, "unbalanced trace"
assert depth == 0, "unclosed phase spans"
assert any(e["ph"] == "B" for e in evs), "no phase spans recorded"
n = validate_prometheus_text(open(f"{tmp}/metrics.prom").read())
assert n > 100, f"suspiciously small exposition ({n} samples)"
print(f"observability ok: {len(evs)} trace events, {n} exposition samples")
EOF
rm -rf "$OBS_TMP"

# --- multi-device: mesh-sharded serving ------------------------------------
# Fresh processes with 8 forced host devices (the main suite and benches
# above must keep their 1-device view — tests/conftest.py): the TP parity
# matrix, the TP=2 retrace gate, and the tp1/tp2/tp4 sharded bench rows
# (token-identical outputs, per-device KV bytes 1/TP, O(1) census).
echo "== multi-device (XLA_FLAGS=--xla_force_host_platform_device_count=8) =="
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m pytest -x -q tests/test_mesh_serving.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  python -m repro.analysis --no-lint --no-kernel-check
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
  PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
  SERVING_BENCH_TINY=1 SERVING_BENCH_MESH_ONLY=1 \
  python benchmarks/serving_bench.py

# --- full test suite -------------------------------------------------------
exec python -m pytest -x -q "$@"

#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): the full pytest suite from the repo root.
# Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"

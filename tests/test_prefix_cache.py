"""Prefix caching: refcounted copy-on-write page sharing across requests.

Covers the allocator primitives (lookup/map_prefix/publish, refcounts,
cached-free LRU reclaim), warm-vs-cold token parity through the engine
(xla + pallas, greedy + seeded), refcount invariants under
retire/preempt/re-admit, reclaim under an oversubscribed pool, and the
explicit cold-prefill fallback for architectures with per-slot state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine
from repro.serve.paged import (NULL_PAGE, PageAllocator, PagedCacheConfig,
                               PagePoolExhausted, block_hashes)

FCFG = FamousConfig(impl="xla")


def _params(cfg):
    return module.init_params(transformer.model_spec(cfg),
                              jax.random.PRNGKey(0), jnp.float32)


def _run(engine, prompts, rid0=0, max_new=4, **req_kw):
    reqs = [Request(rid=rid0 + i, tokens=list(p), max_new=max_new, **req_kw)
            for i, p in enumerate(prompts)]
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    assert len(done) == len(prompts)
    assert all(r.error is None for r in done), [r.error for r in done]
    return [r.out for r in done]


# ---------------------------------------------------------------------------
# allocator: refcounts, index, LRU
# ---------------------------------------------------------------------------


def test_block_hashes_are_chained():
    """Equal blocks under different prefixes must NOT collide: block j's
    hash covers blocks 0..j."""
    ps = 4
    a = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], ps)
    b = block_hashes([5, 6, 7, 8, 9, 9, 9, 9], ps)
    c = block_hashes([1, 2, 3, 4, 9, 9, 9, 9, 1], ps)  # partial tail ignored
    assert len(a) == len(b) == len(c) == 2
    assert a[0] != b[0] and a[1] != b[1]       # same 2nd block, diff prefix
    assert a == c


def test_refcounts_share_and_release():
    cfg = PagedCacheConfig(page_size=4, n_pages=9)
    alloc = PageAllocator(cfg, n_slots=3, max_seq=16)
    hashes = block_hashes(list(range(8)), 4)
    alloc.grow(0, 8)                     # 2 private pages
    alloc.publish(0, hashes)
    pages = [int(p) for p in alloc.page_table[0, :2]]
    # a second slot aliases the published pages
    assert alloc.lookup(hashes) == pages
    alloc.map_prefix(1, pages)
    assert [alloc.refcount(p) for p in pages] == [2, 2]
    assert alloc.pages_shared(1) == 2 and alloc.pages_shared(0) == 0
    alloc.assert_invariants()
    # owner retires: refcount drops to 1, pages stay live for slot 1
    alloc.free(0)
    assert [alloc.refcount(p) for p in pages] == [1, 1]
    assert alloc.cached_free_pages == 0
    # last holder retires: refcount 0 -> cached-free LRU, still indexed
    alloc.free(1)
    assert [alloc.refcount(p) for p in pages] == [0, 0]
    assert alloc.cached_free_pages == 2
    assert alloc.lookup(hashes) == pages       # warm
    alloc.assert_invariants()


def test_lru_reclaim_evicts_oldest_and_unindexes():
    cfg = PagedCacheConfig(page_size=4, n_pages=5)   # 4 allocatable
    alloc = PageAllocator(cfg, n_slots=2, max_seq=16)
    h_a = block_hashes([1] * 8, 4)
    h_b = block_hashes([2] * 8, 4)
    alloc.grow(0, 8); alloc.publish(0, h_a); alloc.free(0)
    alloc.grow(0, 8); alloc.publish(0, h_b); alloc.free(0)
    assert alloc.cached_free_pages == 4 and alloc.free_pages == 4
    # allocating 3 fresh pages must reclaim from the LRU oldest-first:
    # both of A's pages (older) and one of B's go, evicting their hashes
    alloc.grow(1, 12)
    alloc.assert_invariants()
    assert alloc.lookup(h_a) == []
    assert len(alloc.lookup(h_b)) <= 1
    # and a warm cache never blocks: the pool is still fully allocatable
    alloc.free(1)
    assert alloc.free_pages == 4


def test_map_prefix_pins_pages_against_reclaim():
    cfg = PagedCacheConfig(page_size=4, n_pages=4)   # 3 allocatable
    alloc = PageAllocator(cfg, n_slots=2, max_seq=12)
    h = block_hashes([3] * 8, 4)
    alloc.grow(0, 8); alloc.publish(0, h); alloc.free(0)
    pages = alloc.lookup(h)
    alloc.map_prefix(1, pages)           # pinned: refcount 1, off the LRU
    with pytest.raises(PagePoolExhausted):
        alloc.grow(0, 8)                 # only 1 page left, needs 2
    alloc.assert_invariants()
    assert alloc.lookup(h) == pages      # the hit survived the failed grow


def test_can_admit_discounts_lru_hits():
    """Cached-free hit pages are about to be pinned by the admission — they
    cannot double as the fresh capacity the same admission needs."""
    cfg = PagedCacheConfig(page_size=4, n_pages=5)   # 4 allocatable
    alloc = PageAllocator(cfg, n_slots=2, max_seq=16)
    h = block_hashes([4] * 8, 4)
    alloc.grow(0, 8); alloc.publish(0, h); alloc.free(0)   # 2 pages -> LRU
    alloc.grow(1, 8)                                       # 2 pages live
    hits = alloc.lookup(h)
    assert len(hits) == 2 and alloc.free_pages == 2        # both on the LRU
    # 16 tokens = 4 pages: 2 hits + 2 fresh, but the only reclaimable pages
    # ARE the hits — naively `need - hits <= free_pages` would wrongly pass
    assert not alloc.can_admit(16, hits=hits)
    assert alloc.can_admit(8, hits=hits)                   # 2 hits + 0 fresh


# ---------------------------------------------------------------------------
# engine: warm == cold parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_warm_hits_token_identical_greedy(impl):
    """Shared-prefix workload served cold, then warm through the same
    engine: outputs token-identical to the uncached paged engine, pages
    actually aliased, executables still O(1).  Prompt lengths straddle
    page boundaries (partial last block stays private: the COW rule)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    fcfg = FamousConfig(impl=impl)
    rng = np.random.default_rng(0)
    shared = list(rng.integers(0, cfg.vocab_size, size=19))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=k))
               for k in (1, 5, 13)]     # lens 20, 24, 32 over pages of 8
    cold_eng = ServingEngine(params, cfg, fcfg, n_slots=2, max_seq=64,
                             cache_kind="paged", page_size=8)
    cold = _run(cold_eng, prompts)
    eng = ServingEngine(params, cfg, fcfg, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=8, prefix_cache=True)
    first = _run(eng, prompts)
    hits_first = eng.prefix_hit_pages
    with retrace_guard(eng, label="warm prefix-cache run"):
        warm = _run(eng, prompts, rid0=10)
    assert cold == first == warm
    assert eng.prefix_hit_pages - hits_first >= 3 * 2  # >= 2 shared pages each
    eng.alloc.assert_invariants()


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_warm_hits_token_identical_greedy_int8(impl):
    """Int8 pages republish and alias exactly: block hashes cover token
    ids (not pool bytes), and a warm hit re-reads the very int8 payload +
    scale rows the cold run wrote — so warm == cold holds token-for-token
    even though quantization is lossy vs fp.  Scale rows share the
    payload's page ids, so refcounts/reclaim need no extra bookkeeping
    (assert_invariants covers both)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    fcfg = FamousConfig(impl=impl)
    rng = np.random.default_rng(6)
    shared = list(rng.integers(0, cfg.vocab_size, size=19))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=k))
               for k in (1, 5, 13)]
    cold_eng = ServingEngine(params, cfg, fcfg, n_slots=2, max_seq=64,
                             cache_kind="paged", page_size=8,
                             kv_dtype="int8")
    cold = _run(cold_eng, prompts)
    eng = ServingEngine(params, cfg, fcfg, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=8, prefix_cache=True,
                        kv_dtype="int8")
    first = _run(eng, prompts)
    hits_first = eng.prefix_hit_pages
    with retrace_guard(eng, label="warm int8 prefix-cache run"):
        warm = _run(eng, prompts, rid0=10)
    assert cold == first == warm
    assert eng.prefix_hit_pages - hits_first >= 3 * 2
    eng.alloc.assert_invariants()
    # the quantized caches really are quantized: int8 payload pools live
    # in the tree (scale pools ride alongside them)
    assert any(l.dtype == jnp.int8
               for l in jax.tree_util.tree_leaves(eng.caches))


def test_warm_hits_token_identical_seeded_sampling():
    """Seeded sampling is keyed by (seed, token index) only — a warm hit
    must reproduce the cold run's sampled tokens exactly."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(1)
    shared = list(rng.integers(0, cfg.vocab_size, size=17))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=k))
               for k in (2, 9)]
    kw = dict(temperature=0.8, top_k=5, seed=42)
    cold_eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                             cache_kind="paged", page_size=8)
    cold = _run(cold_eng, prompts, max_new=6, **kw)
    eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=8, prefix_cache=True)
    first = _run(eng, prompts, max_new=6, **kw)
    warm = _run(eng, prompts, rid0=10, max_new=6, **kw)
    assert cold == first == warm
    assert eng.prefix_hit_pages > 0


def test_fully_cached_prompt_skips_prefill():
    """A repeated prompt whose cacheable head covers everything but the
    last token admits straight to DECODE — and the page holding position
    n-1 is still private (decode writes it)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(2)
    prompt = [list(rng.integers(0, cfg.vocab_size, size=17))]  # target 16 = 2*8
    base = _run(ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64), prompt)
    eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=8, prefix_cache=True)
    a = _run(eng, prompt)
    b = _run(eng, prompt, rid0=1)
    assert base == a == b
    assert eng.prefix_hit_tokens == 16    # both full blocks of the head
    f = eng.sched.fairness(1)
    assert f["cached_tokens"] == 16 and f.get("prefill_tokens", 0) == 0
    eng.alloc.assert_invariants()


# ---------------------------------------------------------------------------
# engine: refcounts under retire / preempt / re-admit, LRU under pressure
# ---------------------------------------------------------------------------


def test_refcounts_under_preempt_and_readmit():
    """Decode-time growth on a tiny pool forces preemption of slots that
    hold aliased prefix pages; resume re-maps the (still-indexed) prefix
    and the whole run stays token-identical to contiguous serving."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(3)
    shared = list(rng.integers(0, cfg.vocab_size, size=4))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=3))
               for _ in range(2)]
    base = _run(ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=32),
                prompts, max_new=8)
    # 5 allocatable pages of 4: both admit (2 pages each), growth collides
    eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=32,
                        cache_kind="paged", page_size=4, n_pages=6,
                        prefix_cache=True)
    w1 = _run(eng, prompts, max_new=8)
    w2 = _run(eng, prompts, rid0=10, max_new=8)
    assert base == w1 == w2
    eng.alloc.assert_invariants()
    # drained: nothing live, every allocatable page free or warm
    assert eng.alloc.free_pages == 5


def test_lru_reclaim_engine_oversubscribed():
    """More distinct prefixes than the pool can keep warm: old index
    entries are reclaimed on demand and every request still completes,
    token-identically to the uncached engine."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (9, 17, 12, 21, 8, 15)]
    base = _run(ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64),
                prompts)
    # pool of 7 allocatable pages of 8 — fewer than the 8 block hashes the
    # six prompts publish plus live growth; the LRU must cycle
    eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=8, n_pages=8,
                        prefix_cache=True)
    w1 = _run(eng, prompts)
    w2 = _run(eng, prompts, rid0=10)
    assert base == w1 == w2
    eng.alloc.assert_invariants()


def test_hybrid_arch_falls_back_to_cold_prefill():
    """Per-slot recurrent/ring state is not prefix-shareable: the engine
    explicitly disables sharing (prefix_cache_active False) and serves
    every request cold — token-identical, zero hits."""
    cfg = shrink(get_config("recurrentgemma-2b"))
    params = _params(cfg)
    rng = np.random.default_rng(5)
    shared = list(rng.integers(0, cfg.vocab_size, size=16))
    prompts = [shared + list(rng.integers(0, cfg.vocab_size, size=k))
               for k in (3, 7)]
    base = _run(ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64),
                prompts)
    eng = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                        cache_kind="paged", page_size=16, prefix_cache=True)
    assert not eng.prefix_cache_active and eng.prefix_shareable is False
    w1 = _run(eng, prompts)
    w2 = _run(eng, prompts, rid0=10)
    assert base == w1 == w2
    assert eng.prefix_hit_pages == 0 and eng.prefix_lookups == 0


def test_prefix_cache_requires_paged_chunked():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    with pytest.raises(AssertionError):
        ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                      prefix_cache=True)                    # contiguous
    with pytest.raises(AssertionError):
        ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                      cache_kind="paged", page_size=8,
                      prefill_mode="monolithic", prefix_cache=True)

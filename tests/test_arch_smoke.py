"""Per-architecture smoke tests (the assignment's required reduced-config
tests): instantiate a REDUCED config of the same family and run one forward
AND one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SMOKE_SHAPES, get_config, list_configs, shrink
from repro.core.famous import FamousConfig
from repro.models import frontends, module, transformer
from repro.optim import adamw
from repro.train import step as step_lib

ARCHS = [a for a in list_configs()]


def _inputs(cfg, B=2, S=32, seed=1):
    if cfg.frontend:
        return frontends.synthetic_embeddings(cfg, B, S, seed=seed)
    return jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = shrink(get_config(arch))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    x = _inputs(cfg)
    logits = transformer.forward(params, x, cfg, remat=False)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = shrink(get_config(arch))
    tcfg = step_lib.TrainConfig(compute_dtype=jnp.float32, loss_chunk=16)
    state = step_lib.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    ts = step_lib.make_train_step(cfg, FamousConfig(impl="xla"), tcfg)
    x = _inputs(cfg)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                             cfg.vocab_size)
    state, metrics = jax.jit(ts)(state, {"inputs": x, "targets": tgt})
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(state["step"]) == 1
    # params actually changed and stayed finite
    leaves = jax.tree_util.tree_leaves(state["params"])
    assert all(bool(jnp.isfinite(l).all()) for l in leaves), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder_only])
def test_decode_consistency(arch):
    """prefill(first half) + decode(second half) == full forward logits."""
    cfg = shrink(get_config(arch))
    if cfg.frontend:
        pytest.skip("frontend-stub archs decode from embeddings; covered by "
                    "the llava/hubert forward tests")
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full = transformer.forward(params, toks, cfg, remat=False)
    caches = transformer.make_caches(cfg, B, S, jnp.float32)
    lg, caches = transformer.prefill(params, toks[:, :8], caches, cfg)
    errs = [np.abs(np.asarray(lg) - np.asarray(full[:, 7])).max()]
    clen = jnp.full((B,), 8, jnp.int32)
    for t in range(8, 12):
        lg, caches = transformer.decode_step(params, toks[:, t], caches,
                                             clen, cfg)
        clen = clen + 1
        errs.append(np.abs(np.asarray(lg) - np.asarray(full[:, t])).max())
    tol = 5e-2 if cfg.num_experts else 5e-4  # MoE capacity-drop variance
    assert max(errs) < tol, (arch, errs)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive_and_spec_consistent(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 0
    if cfg.num_experts:
        assert cfg.active_param_count() < n
    else:
        assert cfg.active_param_count() == n


def test_full_param_counts_roughly_match_names():
    """Sanity: the full configs land in the advertised parameter class."""
    expect = {
        "qwen2-7b": (6e9, 9e9),
        "qwen3-32b": (28e9, 40e9),
        "deepseek-7b": (6e9, 8.5e9),
        "command-r-plus-104b": (90e9, 120e9),
        "grok-1-314b": (250e9, 340e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "llava-next-34b": (30e9, 40e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"

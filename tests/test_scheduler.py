"""Scheduler (pure policy) unit tests + Scheduler/Runtime integration:
token-budget math, FIFO chunk allocation, youngest-first preemption
choice, fairness accounting, decode-between-prefill-chunks interleaving,
and the O(1)-compilation guarantee the chunked runtime exists for."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import (DECODE, FREE, PREFILL, Scheduler,
                                   SchedulerConfig)

FCFG = FamousConfig(impl="xla")


def _params(cfg):
    return module.init_params(transformer.model_spec(cfg),
                              jax.random.PRNGKey(0), jnp.float32)


# ---------------------------------------------------------------------------
# pure policy (no jax, no engine)
# ---------------------------------------------------------------------------


def _req(rid, n):
    return Request(rid=rid, tokens=list(range(1, n + 1)))


def test_bind_and_chunk_lifecycle():
    s = Scheduler(2, SchedulerConfig(chunk=8))
    assert s.bind(0, _req(0, 20), 20) == PREFILL
    assert s.slots[0].target == 19
    assert not s.on_chunk(0, 8)
    assert not s.on_chunk(0, 8)
    assert s.on_chunk(0, 3)           # 19 done -> DECODE
    assert s.slots[0].state == DECODE
    assert s.bind(1, _req(1, 1), 1) == DECODE  # nothing to prefill


def test_plan_budget_one_chunk_while_decoding():
    """Default budget (n_slots + chunk): exactly one prefill chunk per step
    while decodes are active — decode never starves behind a long prompt."""
    s = Scheduler(4, SchedulerConfig(chunk=8))
    for i in range(3):
        s.bind(i, _req(i, 2), 2)
        s.mark_prefilled(i)
    s.bind(3, _req(3, 65), 65)        # long prompt: 64 tokens to prefill
    plan = s.plan()
    assert plan.decode_slots == [0, 1, 2]
    assert len(plan.chunks) == 1
    assert (plan.chunks[0].slot, plan.chunks[0].start, plan.chunks[0].n) \
        == (3, 0, 8)


def test_plan_decode_width_charges_verify_cost():
    """Speculative serving sets decode_width = draft_k + 1: a decoding
    slot is charged the verify executable's full fixed width, so prefill
    chunks are granted against the step's TRUE compute — while the
    default budget widens in lockstep (one chunk per step still fits)."""
    s = Scheduler(4, SchedulerConfig(chunk=8, decode_width=4))
    for i in range(3):
        s.bind(i, _req(i, 2), 2)
        s.mark_prefilled(i)
    s.bind(3, _req(3, 65), 65)
    plan = s.plan()                   # default budget 4*4 + 8 = 24
    assert plan.decode_slots == [0, 1, 2]
    assert len(plan.chunks) == 1      # 24 - 3*4 = 12 -> one 8-token chunk
    # an explicit budget is consumed decode_width per decoding slot:
    # 24 - 3*4 = 12 leaves one chunk, where width-1 accounting (24 - 3)
    # would have granted two
    s2 = Scheduler(4, SchedulerConfig(chunk=8, token_budget=24,
                                      decode_width=4))
    for i in range(3):
        s2.bind(i, _req(i, 2), 2)
        s2.mark_prefilled(i)
    s2.bind(3, _req(3, 65), 65)
    assert len(s2.plan().chunks) == 1
    s3 = Scheduler(4, SchedulerConfig(chunk=8, token_budget=24))
    for i in range(3):
        s3.bind(i, _req(i, 2), 2)
        s3.mark_prefilled(i)
    s3.bind(3, _req(3, 65), 65)
    assert len(s3.plan().chunks) == 2


def test_on_draft_accounting_reaches_fairness():
    s = Scheduler(1, SchedulerConfig(chunk=8))
    s.bind(0, _req(0, 2), 2)
    s.mark_prefilled(0)
    s.on_draft(0, drafted=4, accepted=2)
    s.on_draft(0, drafted=3, accepted=0)
    st = s.fairness(0)
    assert st["drafted_tokens"] == 7
    assert st["accepted_tokens"] == 2


def test_plan_idle_engine_spends_whole_budget_on_prefill():
    s = Scheduler(2, SchedulerConfig(chunk=8, token_budget=32))
    s.bind(0, _req(0, 65), 65)
    plan = s.plan()
    assert plan.decode_slots == []
    assert [c.start for c in plan.chunks] == [0, 8, 16, 24]
    assert all(c.n == 8 for c in plan.chunks)


def test_plan_grants_minimum_one_chunk():
    """Forward progress even when decodes alone exceed the budget."""
    s = Scheduler(4, SchedulerConfig(chunk=8, token_budget=2))
    for i in range(3):
        s.bind(i, _req(i, 2), 2)
        s.mark_prefilled(i)
    s.bind(3, _req(3, 30), 30)
    plan = s.plan()
    assert len(plan.chunks) == 1


def test_plan_fifo_oldest_prefill_first():
    s = Scheduler(2, SchedulerConfig(chunk=8, token_budget=16))
    s.bind(1, _req(0, 33), 33)        # admitted first (into slot 1)
    s.bind(0, _req(1, 33), 33)
    plan = s.plan()
    assert [c.slot for c in plan.chunks] == [1, 1]  # finish the elder first


def test_final_chunk_is_partial():
    s = Scheduler(1, SchedulerConfig(chunk=8, token_budget=64))
    s.bind(0, _req(0, 12), 12)        # target 11 -> chunks of 8 and 3
    plan = s.plan()
    assert [(c.start, c.n) for c in plan.chunks] == [(0, 8), (8, 3)]


def test_preempt_victim_youngest_including_prefilling():
    s = Scheduler(3, SchedulerConfig(chunk=8))
    s.bind(0, _req(0, 5), 5)
    s.mark_prefilled(0)
    s.bind(1, _req(1, 5), 5)
    s.mark_prefilled(1)
    s.bind(2, _req(2, 30), 30)        # youngest, still prefilling
    assert s.preempt_victim() == 2
    assert s.preempt_victim(exclude=(2,)) == 1
    req = s.preempt(2)
    assert req.rid == 2 and s.slots[2].state == FREE
    assert s.stats[2]["preemptions"] == 1


def test_bind_cached_prefix_starts_prefill_at_first_uncached_token():
    """Prefix-cache admission: bind(cached=) skips the cached head — the
    first chunk starts there, and a fully-cached target goes straight to
    DECODE with the saving on the fairness ledger."""
    s = Scheduler(2, SchedulerConfig(chunk=8, token_budget=64))
    assert s.bind(0, _req(0, 21), 21, cached=16) == PREFILL   # target 20
    plan = s.plan()
    assert [(c.start, c.n) for c in plan.chunks if c.slot == 0] == [(16, 4)]
    assert s.fairness(0)["cached_tokens"] == 16
    # cached >= target: nothing to prefill at all
    assert s.bind(1, _req(1, 17), 17, cached=16) == DECODE
    assert s.slots[1].done == s.slots[1].target == 16
    assert s.fairness(1)["cached_tokens"] == 16


def test_fairness_accounting():
    s = Scheduler(1, SchedulerConfig(chunk=4))
    r = _req(7, 9)
    s.enqueue(r)
    s.tick(); s.tick()                # queued for 2 steps
    s.bind(0, s.pop_queued(), 9)
    s.on_chunk(0, 4); s.on_chunk(0, 4)
    s.on_decode_token(0)
    f = s.fairness(7)
    assert f["enqueue_step"] == 0 and f["admit_step"] == 2
    assert f["prefill_tokens"] == 8 and f["decode_tokens"] == 1
    assert f["ttft_steps"] == 2


# ---------------------------------------------------------------------------
# integration: the properties the split exists for
# ---------------------------------------------------------------------------


def test_decode_proceeds_between_prefill_chunks():
    """The acceptance check: while a long prompt prefills chunk by chunk,
    an already-decoding request keeps emitting tokens every step."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(0)
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=128,
                           chunk=16)
    short = Request(rid=0, tokens=list(rng.integers(0, cfg.vocab_size, 5)),
                    max_new=30)
    engine.add_request(short)
    engine.step()
    long = Request(rid=1, tokens=list(rng.integers(0, cfg.vocab_size, 100)),
                   max_new=4)
    engine.add_request(long)
    interleaved = 0
    prefill_steps = 0
    while engine.sched.slots[1].state == PREFILL:
        before = len(short.out)
        engine.step()
        prefill_steps += 1
        if len(short.out) > before:
            interleaved += 1
    assert prefill_steps >= 6          # 99 tokens / 16-chunk -> 7 steps
    assert interleaved >= prefill_steps - 1  # decode ran alongside chunks
    # and the long prompt still completes correctly afterwards
    done = engine.run([])
    assert {r.rid for r in done} == {0, 1}


def test_prefill_compilations_o1_mixed_lengths():
    """Regression for the unbounded ``_prefill_exec`` growth on
    exact-length (recurrent) prefill: 20 requests of 16 distinct lengths
    through a recurrent arch compile exactly ONE prefill executable."""
    cfg = shrink(get_config("rwkv6-1.6b"))
    params = _params(cfg)
    rng = np.random.default_rng(1)
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64,
                           chunk=16)
    lens = list(range(2, 61, 3))          # 20 distinct prompt lengths
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, n)),
                    max_new=2) for i, n in enumerate(lens)]
    done = engine.run(reqs)
    assert len(done) == len(lens) == 20
    assert engine.prefill_compilations == 1


def test_total_compilations_bounded():
    """O(1) executables for any prompt-length mix: the first batch pays
    the warmup compiles (chunk, decode, and the clear used by
    single-token admissions); a second, differently-mixed batch through
    the warm engine must compile nothing at all (retrace_guard)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(2)
    engine = ServingEngine(params, cfg, FCFG, n_slots=4, max_seq=128,
                           chunk=16)
    lens = [1, 3, 9, 17, 33, 64, 100, 5, 27, 2]
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, n)),
                    max_new=3) for i, n in enumerate(lens)]
    done = engine.run(reqs)
    assert len(done) == len(lens)
    lens2 = [4, 1, 50, 8, 31]
    reqs2 = [Request(rid=100 + i,
                     tokens=list(rng.integers(0, cfg.vocab_size, n)),
                     max_new=3) for i, n in enumerate(lens2)]
    with retrace_guard(engine, label="steady-state mixed batch"):
        done2 = engine.run(reqs2)
    assert len(done2) == len(lens2)


def test_scheduler_stats_reach_engine_requests():
    """TTFT/TPOT raw material: wall-clock marks land on the requests and
    the scheduler ledger sees every served token."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(3)
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64, chunk=8)
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, 9)),
                    max_new=4) for i in range(3)]
    done = engine.run(reqs)
    for r in done:
        assert r.t_submit is not None and r.t_first is not None
        assert r.t_done is not None and r.t_done >= r.t_first >= r.t_submit
        f = engine.sched.fairness(r.rid)
        assert f["decode_tokens"] == 4 and f["prefill_tokens"] == 8

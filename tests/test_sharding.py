"""Sharding-rule resolution: divisibility fallbacks, axis dedup, dp prefix
shrinking, tree mapping.  Uses AbstractMesh so 16-way axes can be tested on
a 1-device host (spec resolution only reads names/sizes)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import abstract_mesh, make_mesh
from repro.parallel import sharding as shd

MESH2 = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _real_mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_spec_basic():
    assert shd.spec_for_axes(MESH2, ("embed", "mlp")) == P("data", "model")


def test_divisibility_fallback_drops_axis():
    # hubert vocab 504 is not divisible by the 16-way model axis
    spec = shd.spec_for_axes(MESH2, ("vocab", "embed"), shape=(504, 1280))
    assert spec == P(None, "data")


def test_heads_fallback():
    # qwen2's 28 heads don't divide 16 -> replicate; embed still FSDP-sharded
    spec = shd.spec_for_axes(MESH2, ("embed", "heads", "head_dim"),
                             shape=(3584, 28, 128))
    assert spec == P("data", None, None)
    # command-r's 96 heads do divide
    spec = shd.spec_for_axes(MESH2, ("embed", "heads", "head_dim"),
                             shape=(12288, 96, 128))
    assert spec == P("data", "model", None)


def test_tuple_prefix_fallback():
    # batch 2 on a (pod=2, data=16) dp tuple -> falls back to ("pod",)
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(2, 8))
    assert spec == P(("pod",), None) or spec == P("pod", None)
    # batch 1 -> fully replicated
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(1, 8))
    assert spec == P(None, None)
    # batch 256 -> full dp tuple
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(256, 8))
    assert spec == P(("pod", "data"), None)


def test_axis_used_once():
    spec = shd.spec_for_axes(MESH2, ("mlp", "heads"), shape=(256, 32))
    assert spec == P("model", None)


def test_missing_mesh_axis_dropped():
    spec = shd.spec_for_axes(MESH2, ("batch",), shape=(256,))
    assert spec == P("data")  # no "pod" on the single-pod mesh


def test_tree_shardings_with_shapes():
    mesh = _real_mesh()
    axes_tree = {"w": ("embed", "mlp"), "b": ("mlp",)}
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
              "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
    out = shd.tree_shardings(mesh, axes_tree, None, shapes)
    assert out["w"].spec == P("data", "model")
    assert out["b"].spec == P("model")


def test_dp_helpers():
    assert shd.dp_axes(MESH3) == ("pod", "data")
    assert shd.dp_size(MESH3) == 32
    assert shd.dp_size(MESH2) == 16

"""Sharding-rule resolution: divisibility fallbacks, axis dedup, dp prefix
shrinking, tree mapping.  Uses AbstractMesh so 16-way axes can be tested on
a 1-device host (spec resolution only reads names/sizes)."""
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import abstract_mesh, make_mesh
from repro.parallel import sharding as shd

MESH2 = abstract_mesh((16, 16), ("data", "model"))
MESH3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _real_mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_spec_basic():
    assert shd.spec_for_axes(MESH2, ("embed", "mlp")) == P("data", "model")


def test_divisibility_fallback_drops_axis():
    # hubert vocab 504 is not divisible by the 16-way model axis
    spec = shd.spec_for_axes(MESH2, ("vocab", "embed"), shape=(504, 1280))
    assert spec == P(None, "data")


def test_heads_fallback():
    # qwen2's 28 heads don't divide 16 -> replicate; embed still FSDP-sharded
    spec = shd.spec_for_axes(MESH2, ("embed", "heads", "head_dim"),
                             shape=(3584, 28, 128))
    assert spec == P("data", None, None)
    # command-r's 96 heads do divide
    spec = shd.spec_for_axes(MESH2, ("embed", "heads", "head_dim"),
                             shape=(12288, 96, 128))
    assert spec == P("data", "model", None)


def test_tuple_prefix_fallback():
    # batch 2 on a (pod=2, data=16) dp tuple -> falls back to ("pod",)
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(2, 8))
    assert spec == P(("pod",), None) or spec == P("pod", None)
    # batch 1 -> fully replicated
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(1, 8))
    assert spec == P(None, None)
    # batch 256 -> full dp tuple
    spec = shd.spec_for_axes(MESH3, ("batch", None), shape=(256, 8))
    assert spec == P(("pod", "data"), None)


def test_axis_used_once():
    spec = shd.spec_for_axes(MESH2, ("mlp", "heads"), shape=(256, 32))
    assert spec == P("model", None)


def test_missing_mesh_axis_dropped():
    spec = shd.spec_for_axes(MESH2, ("batch",), shape=(256,))
    assert spec == P("data")  # no "pod" on the single-pod mesh


def test_tree_shardings_with_shapes():
    mesh = _real_mesh()
    axes_tree = {"w": ("embed", "mlp"), "b": ("mlp",)}
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
              "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
    out = shd.tree_shardings(mesh, axes_tree, None, shapes)
    assert out["w"].spec == P("data", "model")
    assert out["b"].spec == P("model")


MESH4 = abstract_mesh((1, 4), ("data", "model"))


def test_replicate_fallback_warns_once():
    """A non-divisible ruled dim replicates with ONE RuntimeWarning per
    distinct (axis, dim, mesh-axes) combo — not one per tree leaf."""
    shd._REPLICATE_WARNED.clear()
    with pytest.warns(RuntimeWarning, match="kv_heads.*not divisible"):
        # 6 kv heads on a 4-way model axis (the deepseek-ish shape from
        # the issue): replicated, not an XLA placement error
        spec = shd.spec_for_axes(MESH4, (None, None, "kv_heads", "head_dim"),
                                 shape=(2, 32, 6, 16))
    assert spec == P(None, None, None, None)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a repeat would now raise
        spec = shd.spec_for_axes(MESH4, (None, None, "kv_heads", "head_dim"),
                                 shape=(2, 32, 6, 16))
    assert spec == P(None, None, None, None)


def test_divisible_path_does_not_warn():
    shd._REPLICATE_WARNED.clear()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        spec = shd.spec_for_axes(MESH4, (None, None, "kv_heads", "head_dim"),
                                 shape=(2, 32, 8, 16))
    assert spec == P(None, None, "model", None)


def test_serve_tp_rules():
    """Serving TP: heads/kv_heads/mlp shard over "model"; vocab and embed
    replicate so logits (and the LM head) come back replicated."""
    rules = shd.SERVE_TP_RULES
    assert shd.spec_for_axes(MESH4, ("embed", "heads", "head_dim"),
                             rules, (64, 8, 16)) == P(None, "model", None)
    assert shd.spec_for_axes(MESH4, ("embed", "vocab"),
                             rules, (64, 256)) == P(None, None)
    assert shd.spec_for_axes(MESH4, ("embed", "mlp"),
                             rules, (64, 128)) == P(None, "model")


def test_dp_helpers():
    assert shd.dp_axes(MESH3) == ("pod", "data")
    assert shd.dp_size(MESH3) == 32
    assert shd.dp_size(MESH2) == 16

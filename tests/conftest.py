import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py sets the 512-device placeholder flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

# The Pallas contract checker is ON for the whole suite (every
# pc.pallas_call launch is validated) unless explicitly disabled with
# REPRO_KERNEL_CHECK=0.  See repro.analysis.kernel_check.
if os.environ.get("REPRO_KERNEL_CHECK", "1") != "0":
    from repro.analysis import kernel_check
    kernel_check.enable()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

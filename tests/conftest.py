import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
# only launch/dryrun.py sets the 512-device placeholder flag.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

"""End-to-end system behaviour: the full launcher path (config -> mesh ->
sharded state -> deterministic pipeline -> fault-tolerant trainer) trains a
real (reduced) model and produces a decreasing loss; the serving launcher
path generates tokens; the dry-run machinery lowers a production cell."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SMOKE_SHAPES
from repro.launch.train import build
from repro.train import trainer as trainer_lib

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_end_to_end_training_loss_decreases(tmp_path):
    cfg, mesh, state, jitted, batch_fn, state_sh = build(
        "famous-bert", SMOKE_SHAPES["smoke_train"], smoke=True)
    tr = trainer_lib.Trainer(
        jitted, state, batch_fn,
        trainer_lib.TrainerConfig(total_steps=20, ckpt_every=10,
                                  ckpt_dir=str(tmp_path / "e2e")))
    with mesh:
        tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert len(losses) == 20
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    """The dry-run entry point lowers+compiles a production cell in a fresh
    process (512 placeholder devices must not leak into this test runner)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "deepseek-7b", "--shape", "prefill_32k", "--mesh", "pod1",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert "ALL CELLS PASSED" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
    assert len(jax.devices()) == 1  # flag did not leak


def test_serve_cli_smoke():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "deepseek-7b",
         "--requests", "3", "--max-new", "3"],
        capture_output=True, text=True, env=env, timeout=900)
    assert "served 3 requests" in out.stdout, out.stdout + out.stderr[-2000:]

"""Speculative decoding: prompt-lookup drafting + batched verification.

The gate is a randomized parity/property harness: ~50 seeded mixes of
(architecture, cache kind, prefix cache, kernel impl, per-request
sampling params, preemption-inducing tiny page pools) must produce token
streams *identical* to ``speculative=False`` — acceptance/rollback may
only change *when* tokens appear, never *which* tokens.  Around it:
drafter unit tests, logits-level verify-vs-sequential-decode parity,
rollback edge cases (page-boundary rejection, preempt-mid-verify,
fully-rejected drafts, ``max_new`` reached mid-accept), draft-failure
isolation, the executable census under ``retrace_guard``, and the
explicit plain-decode fallback for recurrent/hybrid stacks.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace_guard import retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.draft import PromptLookupDrafter
from repro.serve.engine import Request, ServingEngine
from repro.serve.paged import PagedCacheConfig

MAX_SEQ = 32
CHUNK = 8
ARCHS = ("qwen2-7b", "recurrentgemma-2b", "rwkv6-1.6b")


@functools.lru_cache(maxsize=None)
def _cfg_params(arch):
    cfg = shrink(get_config(arch))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _serve(params, cfg, reqs, **kw):
    eng = ServingEngine(params, cfg, kw.pop("fcfg", FamousConfig(impl="xla")),
                        n_slots=kw.pop("n_slots", 2), max_seq=MAX_SEQ,
                        chunk=CHUNK, **kw)
    done = sorted(eng.run(reqs), key=lambda r: r.rid)
    return done, eng


# ---------------------------------------------------------------------------
# drafter unit tests (pure host policy)
# ---------------------------------------------------------------------------


def test_drafter_empty_cases():
    d = PromptLookupDrafter()
    assert d.draft([1, 2, 3], 0) == []
    assert d.draft([1], 4) == []          # too short for any n-gram + match
    assert d.draft([], 4) == []
    assert d.draft([1, 2, 3, 4], 4) == []  # no repeated n-gram anywhere


def test_drafter_finds_longest_ngram():
    # trailing 3-gram (1,2,3) recurs at the head: the continuation there
    # (9, 1) is the draft
    d = PromptLookupDrafter(max_ngram=3)
    assert d.draft([1, 2, 3, 9, 1, 2, 3], 2) == [9, 1]


def test_drafter_prefers_most_recent_match():
    # trailing (1,2) occurs twice; the LATER occurrence (continuation 8)
    # wins — recency tracks the generation's current phrasing
    d = PromptLookupDrafter(max_ngram=2)
    out = d.draft([5, 1, 2, 7, 1, 2, 8, 1, 2], 3)
    assert out == [8, 1, 2]


def test_drafter_falls_back_to_shorter_ngram():
    # no 2/3-gram repeats, but the trailing 1-gram (4,) recurs; its most
    # recent earlier occurrence (index 2) continues with 7
    d = PromptLookupDrafter(max_ngram=3, min_ngram=1)
    assert d.draft([4, 6, 4, 7, 9, 4], 1) == [7]


def test_drafter_truncates_at_sequence_end():
    # the match sits near the tail: fewer than k continuation tokens exist
    d = PromptLookupDrafter(max_ngram=2)
    assert d.draft([1, 2, 9, 1, 2], 4) == [9, 1, 2]


# ---------------------------------------------------------------------------
# logits-level parity: one verify call == W sequential decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("cache", ["contiguous", "paged", "paged_int8"])
def test_verify_step_matches_sequential_decode(impl, cache):
    """verify_step's row j must equal the logits of the j+1-th sequential
    decode_step over the same tokens (causality makes the parallel and
    sequential activations identical) — the foundation the engine's
    accept rule stands on.  The int8 axis holds because BOTH paths write
    the same quantized values before attending: quantization is lossy vs
    fp, but deterministic, so verify-vs-sequential stays exact."""
    cfg, params = _cfg_params("qwen2-7b")
    fcfg = FamousConfig(impl=impl)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=9)
    W = 4
    ps, n_p = 8, MAX_SEQ // 8
    kw = {}
    if cache.startswith("paged"):
        caches = transformer.make_caches(
            cfg, 1, MAX_SEQ, jnp.float32, cache_kind="paged", page_size=ps,
            n_pages=n_p + 1,
            kv_dtype="int8" if cache == "paged_int8" else "fp")
        # pages 1..n_p back the single slot (page 0 is the null page)
        kw["page_table"] = jnp.arange(1, n_p + 1, dtype=jnp.int32)[None]
    else:
        caches = transformer.make_caches(cfg, 1, MAX_SEQ, jnp.float32)
    seq_caches = caches
    seq_logits = []
    for j, t in enumerate(toks):
        lg, seq_caches = transformer.decode_step(
            params, jnp.asarray([t], jnp.int32), seq_caches,
            jnp.asarray([j], jnp.int32), cfg, fcfg,
            active=jnp.asarray([True]), **kw)
        seq_logits.append(np.asarray(lg[0]))
    # verify the last W tokens in one shot, on top of the first 9 - W
    ver_caches = caches
    L = len(toks) - W
    for j, t in enumerate(toks[:L]):
        _, ver_caches = transformer.decode_step(
            params, jnp.asarray([t], jnp.int32), ver_caches,
            jnp.asarray([j], jnp.int32), cfg, fcfg,
            active=jnp.asarray([True]), **kw)
    vlg, _ = transformer.verify_step(
        params, jnp.asarray(toks[None, L:], jnp.int32), ver_caches,
        jnp.asarray([L], jnp.int32), cfg, fcfg, **kw)
    for j in range(W):
        np.testing.assert_allclose(np.asarray(vlg[0, j]), seq_logits[L + j],
                                   atol=3e-5, rtol=1e-5)


def test_verify_step_rejects_non_attention_stacks():
    cfg, params = _cfg_params("recurrentgemma-2b")
    caches = transformer.make_caches(cfg, 1, MAX_SEQ, jnp.float32)
    with pytest.raises(ValueError, match="global-attention"):
        transformer.verify_step(params, jnp.zeros((1, 3), jnp.int32), caches,
                                jnp.zeros((1,), jnp.int32), cfg,
                                FamousConfig(impl="xla"))


# ---------------------------------------------------------------------------
# the randomized parity/property harness
# ---------------------------------------------------------------------------


def _random_mix(mix_seed):
    """One randomized serving scenario: engine kwargs + request list."""
    rng = np.random.default_rng(10_000 + mix_seed)
    arch = ARCHS[rng.choice(3, p=[0.7, 0.15, 0.15])]
    cfg, params = _cfg_params(arch)
    impl = "pallas" if rng.random() < 0.2 else "xla"
    kw = {"fcfg": FamousConfig(impl=impl),
          "n_slots": int(rng.integers(2, 4)),
          "draft_k": int(rng.integers(1, 6))}
    if rng.random() < 0.5:
        ps = int(rng.choice([4, 8]))
        kw.update(cache_kind="paged", page_size=ps)
        if rng.random() < 0.4:
            # tiny pool: big enough to back any single request, small
            # enough that concurrent slots fight over pages (preemption)
            kw["n_pages"] = (PagedCacheConfig(page_size=ps, n_pages=2)
                             .pages_for(MAX_SEQ) + 1 + int(rng.integers(0, 3)))
        if rng.random() < 0.5:
            kw["prefix_cache"] = True
        if rng.random() < 0.3:
            # quantized KV: spec-vs-plain parity must survive lossy caches
            # (both sides read the same int8 pages)
            kw["kv_dtype"] = "int8"
    reqs = []
    shared = list(map(int, rng.integers(0, cfg.vocab_size, 11)))
    for i in range(int(rng.integers(3, 7))):
        max_new = int(rng.integers(3, 9))
        n = int(rng.integers(1, MAX_SEQ - max_new + 1))
        if rng.random() < 0.5:
            # periodic prompt: the n-gram drafter actually fires on these
            motif = list(map(int, rng.integers(0, cfg.vocab_size, 3)))
            prompt = (motif * MAX_SEQ)[:n]
        elif rng.random() < 0.5:
            prompt = (shared + list(
                map(int, rng.integers(0, cfg.vocab_size, MAX_SEQ))))[:n]
        else:
            prompt = list(map(int, rng.integers(0, cfg.vocab_size, n)))
        greedy = rng.random() < 0.6
        reqs.append(dict(rid=i, tokens=prompt, max_new=max_new,
                         temperature=0.0 if greedy else
                         float(rng.uniform(0.5, 1.0)),
                         top_k=int(rng.choice([0, 4, 8])),
                         seed=int(rng.integers(0, 2**31))))
    return arch, cfg, params, kw, reqs


@pytest.mark.parametrize("mix_seed", range(50))
def test_speculative_parity_random_mix(mix_seed):
    """Speculative serving must be token-identical to plain serving for
    every randomized mix, with no request dropped or errored and the
    allocator invariants intact."""
    arch, cfg, params, kw, req_specs = _random_mix(mix_seed)
    ref, _ = _serve(params, cfg,
                    [Request(**s) for s in req_specs], **dict(kw))
    spec, eng = _serve(params, cfg, [Request(**s) for s in req_specs],
                       speculative=True, **dict(kw))
    assert len(spec) == len(req_specs)
    assert all(r.error is None and r.done for r in ref + spec), \
        [(r.rid, r.error) for r in ref + spec]
    assert [r.out for r in spec] == [r.out for r in ref], (arch, kw)
    if kw.get("cache_kind") == "paged":
        eng.alloc.assert_invariants()
    if arch == "qwen2-7b":
        assert eng.speculative_active
        # verify REPLACED decode: the decode executable never compiled
        assert eng.compilations["decode"] == 0
    else:
        assert not eng.speculative_active   # recurrent/hybrid fallback


# ---------------------------------------------------------------------------
# rollback edge cases (scripted drafters make the accept length exact)
# ---------------------------------------------------------------------------


class ScriptedDrafter:
    """Drafts ``(ref_out[pos + j] + delta) % vocab``: delta=0 is an
    oracle (every draft token accepted), any other delta guarantees the
    first draft token is rejected (fully-rejected drafts)."""

    def __init__(self, prompt_len, ref_out, vocab, delta=0):
        self.prompt_len, self.ref, self.vocab, self.delta = \
            prompt_len, list(ref_out), vocab, delta

    def draft(self, seq, k):
        pos = len(seq) - self.prompt_len
        return [(t + self.delta) % self.vocab
                for t in self.ref[pos:pos + k]]


class PoisonDrafter(PromptLookupDrafter):
    """Raises for one specific prompt; drafts normally for everyone else."""

    def __init__(self, poison_prefix):
        super().__init__()
        self.poison = list(poison_prefix)

    def draft(self, seq, k):
        if seq[:len(self.poison)] == self.poison:
            raise RuntimeError("poisoned request")
        return super().draft(seq, k)


def _ref_out(params, cfg, prompt, max_new, **kw):
    done, _ = _serve(params, cfg,
                     [Request(rid=0, tokens=list(prompt), max_new=max_new)],
                     **kw)
    assert done[0].error is None
    return done[0].out


@pytest.mark.parametrize("kv_dtype", ["fp", "int8"])
def test_rejected_draft_at_page_boundary_frees_pages(kv_dtype):
    """A draft that grows the slot across a page boundary and is then
    fully rejected must give the boundary page back — held pages track
    ``cache_len`` exactly after every step (no leak), and the pool is
    clean after retirement.  The int8 axis checks the scale rows shrink
    in lockstep: they share the freed page ids, so a leak would trip
    ``assert_invariants`` or the held-pages accounting."""
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(3)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 6)))
    # the reference comes from a plain engine with the SAME cache dtype:
    # int8 greedy may lawfully diverge from fp greedy, rejection must not
    ref = _ref_out(params, cfg, prompt, 12, cache_kind="paged", page_size=4,
                   kv_dtype=kv_dtype)
    drafter = ScriptedDrafter(len(prompt), ref, cfg.vocab_size, delta=1)
    eng = ServingEngine(params, cfg, FamousConfig(impl="xla"), n_slots=2,
                        max_seq=MAX_SEQ, chunk=CHUNK, cache_kind="paged",
                        page_size=4, speculative=True, draft_k=5,
                        drafter=drafter, kv_dtype=kv_dtype)
    req = Request(rid=0, tokens=list(prompt), max_new=12)
    eng.sched.enqueue(req)
    eng.add_request(eng.sched.pop_queued())
    while not req.done:
        eng.step()
        eng.alloc.assert_invariants()
        if not req.done:   # slot 0 still live: no draft page survives
            assert eng.alloc.pages_held(0) == \
                eng.pcfg.pages_for(int(eng.cache_len[0]))
    assert req.error is None and req.out == ref
    assert eng.spec_accepted == 0          # every draft token rejected
    assert eng.alloc.free_pages == eng.pcfg.n_pages - 1   # all returned


def test_fully_rejected_drafts_emit_exactly_one_token_per_step():
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(4)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    ref = _ref_out(params, cfg, prompt, 8)
    drafter = ScriptedDrafter(len(prompt), ref, cfg.vocab_size, delta=7)
    done, eng = _serve(params, cfg,
                       [Request(rid=0, tokens=list(prompt), max_new=8)],
                       speculative=True, draft_k=3, drafter=drafter)
    assert done[0].out == ref
    assert eng.spec_accepted == 0 and eng.spec_drafted > 0
    assert eng.spec_steps == len(ref)      # one bonus token per verify step
    assert eng.acceptance_rate == 0.0 and eng.accepted_per_step == 1.0


def test_oracle_drafter_hits_max_new_exactly():
    """``max_new`` reached mid-accept: the draft cap trims the last step's
    width so the request finishes with EXACTLY max_new tokens (no
    overshoot), in fewer verify steps than tokens."""
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(5)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    max_new = 7                            # not a multiple of draft_k + 1
    ref = _ref_out(params, cfg, prompt, max_new)
    drafter = ScriptedDrafter(len(prompt), ref, cfg.vocab_size, delta=0)
    done, eng = _serve(params, cfg,
                       [Request(rid=0, tokens=list(prompt), max_new=max_new)],
                       speculative=True, draft_k=3, drafter=drafter)
    assert done[0].out == ref and len(done[0].out) == max_new
    assert eng.spec_steps == 2             # 4 + 3 tokens, width-capped
    assert eng.spec_accepted == max_new - eng.spec_steps


def test_preemption_mid_speculation_stays_token_identical():
    """A pool too small for all slots forces preemption while drafts are
    in flight; resumed requests must still match plain decode exactly."""
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(6)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 14 + 5 * i)))
               for i in range(3)]

    def reqs():
        return [Request(rid=i, tokens=list(p), max_new=8)
                for i, p in enumerate(prompts)]

    kw = dict(cache_kind="paged", page_size=4,
              n_pages=PagedCacheConfig(page_size=4, n_pages=2)
              .pages_for(MAX_SEQ) + 2)
    ref, _ = _serve(params, cfg, reqs(), **dict(kw))
    spec, eng = _serve(params, cfg, reqs(), speculative=True, draft_k=4,
                       **dict(kw))
    assert all(r.error is None for r in ref + spec)
    assert [r.out for r in spec] == [r.out for r in ref]
    assert sum(st.get("preemptions", 0)
               for st in eng.sched.stats.values()) >= 1
    eng.alloc.assert_invariants()


def test_poisoned_drafter_fails_alone():
    """One request whose drafting raises comes back with ``req.error``
    set; co-scheduled requests finish normally and token-identically."""
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(8)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 6 + 2 * i)))
               for i in range(3)]
    ref, _ = _serve(params, cfg, [Request(rid=i, tokens=list(p), max_new=6)
                                  for i, p in enumerate(prompts)])
    spec, eng = _serve(params, cfg,
                       [Request(rid=i, tokens=list(p), max_new=6)
                        for i, p in enumerate(prompts)],
                       speculative=True, drafter=PoisonDrafter(prompts[1]))
    assert spec[1].error is not None and "poisoned" in spec[1].error
    for i in (0, 2):
        assert spec[i].error is None
        assert spec[i].out == ref[i].out


# ---------------------------------------------------------------------------
# executable census / fallback
# ---------------------------------------------------------------------------


def test_speculative_census_and_retrace():
    """Warmed speculative engine: at most three hot executables (prefill,
    verify, clear), decode never compiled, and a fresh mixed workload
    triggers zero new compilations."""
    cfg, params = _cfg_params("qwen2-7b")
    rng = np.random.default_rng(9)

    def reqs(rid0):
        return [Request(rid=rid0 + i, max_new=4,
                        tokens=list(map(int, rng.integers(
                            0, cfg.vocab_size, 1 + 4 * i))),
                        temperature=0.7 if i == 2 else 0.0, top_k=4)
                for i in range(3)]

    eng = ServingEngine(params, cfg, FamousConfig(impl="xla"), n_slots=2,
                        max_seq=MAX_SEQ, chunk=CHUNK, cache_kind="paged",
                        page_size=8, prefix_cache=True, speculative=True,
                        draft_k=3)
    eng.run(reqs(0))
    census = eng.compilations
    assert census["decode"] == 0
    assert census["prefill"] + census["verify"] + census["clear"] <= 3
    with retrace_guard(eng, label="warm speculative loop"):
        eng.run(reqs(10))


def test_recurrent_arch_falls_back_to_plain_decode():
    """``speculative=True`` on a recurrent stack must not break serving:
    the engine degrades to plain decode explicitly (no verify compile,
    no speculative accounting) and stays token-identical."""
    for arch in ("rwkv6-1.6b", "recurrentgemma-2b"):
        cfg, params = _cfg_params(arch)
        rng = np.random.default_rng(11)
        prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 5 + 3 * i)))
                   for i in range(2)]
        ref, _ = _serve(params, cfg,
                        [Request(rid=i, tokens=list(p), max_new=5)
                         for i, p in enumerate(prompts)])
        spec, eng = _serve(params, cfg,
                           [Request(rid=i, tokens=list(p), max_new=5)
                            for i, p in enumerate(prompts)],
                           speculative=True, draft_k=4)
        assert not eng.speculative_active
        assert eng.spec_steps == 0
        assert eng.compilations["verify"] == 0
        assert eng.compilations["decode"] >= 1
        assert [r.out for r in spec] == [r.out for r in ref]

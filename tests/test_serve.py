"""Serving-engine integration: continuous batching produces exactly the
tokens a sequential prefill+decode loop would, chunked prefill is
token-identical to the monolithic baseline (contiguous + paged, xla +
pallas, attention/hybrid/recurrent archs), long prompts prefill across
many chunks, and per-request sampling is reproducible."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine

FCFG = FamousConfig(impl="xla")


def _params(cfg):
    return module.init_params(transformer.model_spec(cfg),
                              jax.random.PRNGKey(0), jnp.float32)


def _greedy_reference(params, cfg, tokens, max_new):
    """Sequential single-request generation via raw decode steps."""
    caches = transformer.make_caches(cfg, 1, 128, jnp.float32)
    toks = list(tokens)
    if len(toks) > 1:
        _, caches = transformer.prefill(
            params, jnp.asarray([toks[:-1]], jnp.int32), caches, cfg, FCFG)
    clen = jnp.asarray([len(toks) - 1], jnp.int32)
    out = []
    cur = toks[-1]
    for _ in range(max_new):
        logits, caches = transformer.decode_step(
            params, jnp.asarray([cur], jnp.int32), caches, clen, cfg, FCFG)
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
        clen = clen + 1
    return out


def _serve(params, cfg, prompts, max_new, **kw):
    engine = ServingEngine(params, cfg, kw.pop("fcfg", FCFG), **kw)
    reqs = [Request(rid=i, tokens=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    assert len(done) == len(prompts)
    assert all(r.error is None for r in done)
    return [r.out for r in done], engine


@pytest.mark.parametrize("arch", ["qwen2-7b", "recurrentgemma-2b",
                                  "rwkv6-1.6b"])
def test_engine_matches_sequential_reference(arch):
    cfg = shrink(get_config(arch))
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 9, 17, 3)]
    refs = [_greedy_reference(params, cfg, p, 6) for p in prompts]
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=128)
    reqs = [Request(rid=i, tokens=p, max_new=6) for i, p in enumerate(prompts)]
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    for req, ref in zip(done, refs):
        assert req.out == ref, (arch, req.rid, req.out, ref)


# ---------------------------------------------------------------------------
# chunked vs monolithic prefill parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-7b", "recurrentgemma-2b",
                                  "rwkv6-1.6b"])
def test_chunked_matches_monolithic(arch):
    """Token-identical output whether the prompt is prefilled in one
    monolithic call or in fixed-shape chunks between decode steps —
    global-attention, hybrid recurrent/local-attention and pure-recurrent
    stacks, with prompts spanning partial, exact and multi-chunk lengths."""
    cfg = shrink(get_config(arch))
    params = _params(cfg)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (3, 9, 17, 33, 1)]
    mono, _ = _serve(params, cfg, prompts, 5, n_slots=2, max_seq=64,
                     prefill_mode="monolithic")
    chunked, engine = _serve(params, cfg, prompts, 5, n_slots=2, max_seq=64,
                             prefill_mode="chunked", chunk=8)
    assert mono == chunked, arch
    assert engine.prefill_compilations == 1


def test_chunked_matches_monolithic_paged():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 21, 12)]
    mono, _ = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=64,
                     prefill_mode="monolithic", cache_kind="paged",
                     page_size=8)
    chunked, _ = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=64,
                        prefill_mode="chunked", chunk=16, cache_kind="paged",
                        page_size=8)
    assert mono == chunked


def test_chunked_pallas_matches_xla():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n)) for n in (6, 19)]
    xla, _ = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=32, chunk=8)
    pallas, _ = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=32,
                       chunk=8, fcfg=FamousConfig(impl="pallas"))
    assert xla == pallas


def test_long_prompt_spans_many_chunks():
    """A prompt far beyond any single prefill call (> the old engine's
    largest sub-max_seq pow-2 bucket) prefills as a sequence of fixed
    chunks and still matches the monolithic oracle token for token."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=100))]
    mono, _ = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=128,
                     prefill_mode="monolithic")
    chunked, engine = _serve(params, cfg, prompts, 4, n_slots=2, max_seq=128,
                             prefill_mode="chunked", chunk=16)
    assert mono == chunked
    assert engine.prefill_compilations == 1  # 7 chunk calls, one executable


# ---------------------------------------------------------------------------
# legacy monolithic path (kept as the comparison baseline)
# ---------------------------------------------------------------------------


def test_monolithic_bucketing_reuses_executables():
    cfg = shrink(get_config("qwen2-7b"))
    engine = ServingEngine(_params(cfg), cfg, FCFG, n_slots=4, max_seq=64,
                           prefill_mode="monolithic")
    assert engine.bucketed
    rng = np.random.default_rng(1)
    lens = [3, 5, 7, 9, 12, 15, 17, 30]  # -> buckets {2,4,8,16,32}
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, n)),
                    max_new=2) for i, n in enumerate(lens)]
    done = engine.run(reqs)
    assert len(done) == len(lens)
    assert engine.prefill_compilations <= 5  # pow-2 buckets, not per-length


def test_monolithic_recurrent_uses_exact_length():
    cfg = shrink(get_config("rwkv6-1.6b"))
    engine = ServingEngine(_params(cfg), cfg, FCFG, n_slots=2, max_seq=64,
                           prefill_mode="monolithic")
    assert not engine.bucketed


# ---------------------------------------------------------------------------
# per-request sampling
# ---------------------------------------------------------------------------


def test_greedy_default_unchanged():
    """temperature=0 (the default) is plain argmax — identical to the
    sequential greedy reference."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, size=9))
    ref = _greedy_reference(params, cfg, prompt, 5)
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=128)
    done = engine.run([Request(rid=0, tokens=prompt, max_new=5)])
    assert done[0].out == ref


def test_seeded_sampling_reproducible():
    """A seeded request samples the same tokens regardless of batch
    composition or slot placement (key = f(seed, token index) only)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(6)
    prompt = list(rng.integers(0, cfg.vocab_size, size=9))

    def run(extra_prompts, n_slots):
        reqs = [Request(rid=0, tokens=list(prompt), max_new=6,
                        temperature=0.8, top_k=5, seed=42)]
        reqs += [Request(rid=i + 1, tokens=list(p), max_new=6)
                 for i, p in enumerate(extra_prompts)]
        engine = ServingEngine(params, cfg, FCFG, n_slots=n_slots, max_seq=64,
                               chunk=8)
        done = sorted(engine.run(reqs), key=lambda r: r.rid)
        return done[0].out

    alone = run([], 2)
    extras = [list(rng.integers(0, cfg.vocab_size, size=7)) for _ in range(3)]
    crowded = run(extras, 3)
    assert alone == crowded
    # unseeded (seed=None) requests fall back to their rid: two sampling
    # requests with the same prompt draw different noise, not N copies
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64)
    pair = engine.run([Request(rid=i, tokens=list(prompt), max_new=8,
                               temperature=2.0) for i in (0, 1)])
    pair = sorted(pair, key=lambda r: r.rid)
    assert pair[0].out != pair[1].out
    # and a seeded run is actually sampling (top_k > 1, warm temperature):
    # it may coincide with greedy on some steps but the machinery is live —
    # top_k=1 must collapse back to greedy exactly.
    greedy = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64).run(
        [Request(rid=0, tokens=list(prompt), max_new=6)])[0].out
    k1 = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64).run(
        [Request(rid=0, tokens=list(prompt), max_new=6, temperature=0.7,
                 top_k=1, seed=9)])[0].out
    assert k1 == greedy


def test_sample_tokens_topk_matches_full_sort_reference():
    """The lax.top_k thresholding path must be token-identical to the old
    full-vocab-sort sampler for every (temperature, top_k) mix."""
    from repro.serve import sampling

    def reference(logits, temperature, top_k, seed, index):
        def one(lg, t, k, s, idx):
            greedy = jnp.argmax(lg).astype(jnp.int32)
            v = lg.shape[-1]
            kth = jnp.sort(lg)[::-1][jnp.clip(k, 1, v) - 1]
            masked = jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)
            key = jax.random.fold_in(jax.random.PRNGKey(s), idx)
            g = jax.random.gumbel(key, lg.shape, lg.dtype)
            sampled = jnp.argmax(masked / jnp.maximum(t, 1e-6) + g)
            return jnp.where(t > 0, sampled.astype(jnp.int32), greedy)
        return jax.vmap(one)(logits, temperature, top_k, seed, index)

    rng = np.random.default_rng(7)
    B, V = 6, 91
    logits = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    temps = jnp.asarray([0.0, 0.7, 1.3, 0.5, 2.0, 1.0], jnp.float32)
    topks = jnp.asarray([0, 5, 1, 20, 0, 64], jnp.int32)
    seeds = np.asarray([0, 42, 7, 9, 11, 13], np.uint32)
    idxs = jnp.asarray(rng.integers(0, 9, size=B), jnp.int32)
    want = reference(logits, temps, topks, jnp.asarray(seeds), idxs)
    for k_cap in (0, 64, 128):     # cap >= max(top_k): identical thresholds
        got = sampling.sample_tokens(logits, temps, topks,
                                     jnp.asarray(seeds), idxs, k_cap=k_cap)
        assert (np.asarray(want) == np.asarray(got)).all(), k_cap


def test_huge_rid_seed_fallback():
    """seed=None falls back to the request id; rids >= 2^31 must neither
    overflow the seed operand nor collide after uint32 folding."""
    from repro.serve.sampling import fold_seed
    assert fold_seed(42) == 42                       # identity below 2^32
    assert fold_seed(2**40 + 3) != fold_seed(2**41 + 3)
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(8)
    prompt = list(rng.integers(0, cfg.vocab_size, size=6))
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64)
    done = sorted(engine.run(
        [Request(rid=2**40 + i, tokens=list(prompt), max_new=8,
                 temperature=2.0) for i in (0, 1)]), key=lambda r: r.rid)
    assert all(r.error is None and len(r.out) == 8 for r in done)
    assert done[0].out != done[1].out    # distinct rids -> distinct noise


def test_run_max_steps_surfaces_every_request():
    """Exhausting max_steps must return EVERY request — slot-bound
    mid-flight, preempted, and never-admitted alike — with req.error set
    instead of silently dropping them."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, 9)),
                    max_new=4) for i in range(5)]
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=64, chunk=8)
    done = engine.run(reqs, max_steps=1)
    assert sorted(r.rid for r in done) == list(range(5))
    assert all(r.error is not None and not r.done for r in done)
    assert all(r.t_done is not None for r in done)   # terminal timestamp
    assert any("mid-flight" in r.error for r in done)       # the 2 slot-bound
    assert any("never admitted" in r.error for r in done)   # the 3 queued
    # the engine is reusable afterwards: slots and queues were cleaned up
    ok = engine.run([Request(rid=9, tokens=[1, 2, 3], max_new=2)])
    assert len(ok) == 1 and ok[0].error is None and len(ok[0].out) == 2

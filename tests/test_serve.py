"""Serving-engine integration: continuous batching produces exactly the
tokens a sequential prefill+decode loop would, for both bucketed (attention)
and exact-length (recurrent) prefill strategies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine

FCFG = FamousConfig(impl="xla")


def _params(cfg):
    return module.init_params(transformer.model_spec(cfg),
                              jax.random.PRNGKey(0), jnp.float32)


def _greedy_reference(params, cfg, tokens, max_new):
    """Sequential single-request generation via raw decode steps."""
    caches = transformer.make_caches(cfg, 1, 128, jnp.float32)
    toks = list(tokens)
    if len(toks) > 1:
        _, caches = transformer.prefill(
            params, jnp.asarray([toks[:-1]], jnp.int32), caches, cfg, FCFG)
    clen = jnp.asarray([len(toks) - 1], jnp.int32)
    out = []
    cur = toks[-1]
    for _ in range(max_new):
        logits, caches = transformer.decode_step(
            params, jnp.asarray([cur], jnp.int32), caches, clen, cfg, FCFG)
        cur = int(jnp.argmax(logits[0]))
        out.append(cur)
        clen = clen + 1
    return out


@pytest.mark.parametrize("arch", ["qwen2-7b", "recurrentgemma-2b",
                                  "rwkv6-1.6b"])
def test_engine_matches_sequential_reference(arch):
    cfg = shrink(get_config(arch))
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 9, 17, 3)]
    refs = [_greedy_reference(params, cfg, p, 6) for p in prompts]
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=128)
    reqs = [Request(rid=i, tokens=p, max_new=6) for i, p in enumerate(prompts)]
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    for req, ref in zip(done, refs):
        assert req.out == ref, (arch, req.rid, req.out, ref)


def test_bucketing_reuses_executables():
    cfg = shrink(get_config("qwen2-7b"))
    engine = ServingEngine(_params(cfg), cfg, FCFG, n_slots=4, max_seq=64)
    assert engine.bucketed
    rng = np.random.default_rng(1)
    lens = [3, 5, 7, 9, 12, 15, 17, 30]  # -> buckets {2,4,8,16,32}
    reqs = [Request(rid=i, tokens=list(rng.integers(0, cfg.vocab_size, n)),
                    max_new=2) for i, n in enumerate(lens)]
    done = engine.run(reqs)
    assert len(done) == len(lens)
    assert engine.prefill_compilations <= 5  # pow-2 buckets, not per-length


def test_recurrent_engine_uses_exact_length():
    cfg = shrink(get_config("rwkv6-1.6b"))
    engine = ServingEngine(_params(cfg), cfg, FCFG, n_slots=2, max_seq=64)
    assert not engine.bucketed

"""Tests for repro.analysis: lint rules, the Pallas contract checker and
the retrace guard — each rule with a positive and a negative fixture, the
checker against both deliberately broken specs and the real kernels, and
the guard against fake censuses plus a live warmed engine."""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.analysis import (KernelContractError, RetraceError, checking,
                            lint_paths, lint_source, retrace_guard)
from repro.analysis import kernel_check
from repro.analysis import lint


def _rules(src, path="x.py"):
    return [f.rule for f in lint_source(textwrap.dedent(src), path)]


# --------------------------------------------------------------------------
# RA001: host sync in loop
# --------------------------------------------------------------------------

def test_ra001_int_on_device_value_in_loop():
    src = """
    import jax.numpy as jnp

    def f():
        vals = jnp.arange(8)
        out = []
        for i in range(8):
            out.append(int(vals[i]))
        return out
    """
    assert _rules(src) == ["RA001"]


def test_ra001_comprehension_counts_as_loop():
    src = """
    import jax.numpy as jnp

    def f(xs):
        d = jnp.cumsum(xs)
        return [float(d[i]) for i in range(4)]
    """
    assert _rules(src) == ["RA001"]


def test_ra001_device_class_attr():
    src = """
    import jax.numpy as jnp

    class C:
        def __init__(self):
            self.state = jnp.zeros((4,))

        def pull(self):
            return [int(self.state[i]) for i in range(4)]
    """
    assert _rules(src) == ["RA001"]


def test_ra001_negative_host_numpy():
    src = """
    import numpy as np

    def f():
        vals = np.arange(8)
        return [int(vals[i]) for i in range(8)]
    """
    assert _rules(src) == []


def test_ra001_negative_hoisted_pull():
    src = """
    import numpy as np
    import jax.numpy as jnp

    def f():
        vals = jnp.arange(8)
        host = np.asarray(vals)    # the one blessed sync
        return [int(host[i]) for i in range(8)]
    """
    assert _rules(src) == []


# --------------------------------------------------------------------------
# RA002: eager scatter in loop
# --------------------------------------------------------------------------

def test_ra002_scatter_in_loop():
    src = """
    import jax.numpy as jnp

    def f(x):
        for i in range(4):
            x = x.at[i].set(i)
        return x
    """
    assert _rules(src) == ["RA002"]


def test_ra002_negative_outside_loop():
    src = """
    def f(x, i):
        return x.at[i].set(0)
    """
    assert _rules(src) == []


# --------------------------------------------------------------------------
# RA003: jax.jit without static declarations
# --------------------------------------------------------------------------

def test_ra003_jit_of_str_param():
    src = """
    import jax

    def f(x, mode="fast"):
        return x

    def build():
        return jax.jit(f)
    """
    assert _rules(src) == ["RA003"]


def test_ra003_negative_with_static_argnames():
    src = """
    import jax

    def f(x, mode="fast"):
        return x

    def build():
        return jax.jit(f, static_argnames=("mode",))
    """
    assert _rules(src) == []


def test_ra003_negative_no_static_params():
    src = """
    import jax

    def f(x, scale=1.0):
        return x * scale

    def build():
        return jax.jit(f)
    """
    assert _rules(src) == []


# --------------------------------------------------------------------------
# RA004: scheduler purity
# --------------------------------------------------------------------------

def test_ra004_scheduler_must_not_import_jax():
    src = "import jax.numpy as jnp\n"
    assert _rules(src, path="serve/scheduler.py") == ["RA004"]
    # the identical source is fine anywhere else
    assert _rules(src, path="serve/engine.py") == []


def test_ra004_is_never_baselined():
    findings = lint_source("import jax\n", "serve/scheduler.py")
    baseline = {f.fingerprint for f in findings}
    new, _stale = lint.compare_to_baseline(findings, baseline)
    assert [f.rule for f in new] == ["RA004"]


# --------------------------------------------------------------------------
# baseline mechanics + the repo-is-clean gate
# --------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    findings = lint_source(textwrap.dedent("""
    import jax.numpy as jnp

    def f(x):
        for i in range(4):
            x = x.at[i].set(i)
        return x
    """), "m.py")
    path = str(tmp_path / "baseline.txt")
    lint.write_baseline(findings, path)
    baseline = lint.load_baseline(path)
    new, stale = lint.compare_to_baseline(findings, baseline)
    assert not new and not stale
    # fixing the finding turns the entry stale
    new, stale = lint.compare_to_baseline([], baseline)
    assert not new and len(stale) == 1


def test_repo_lints_clean():
    root = os.path.dirname(os.path.dirname(os.path.abspath(lint.__file__)))
    findings = lint_paths(root)
    new, stale = lint.compare_to_baseline(findings, lint.load_baseline())
    assert not new, "new lint findings:\n" + "\n".join(str(f) for f in new)
    assert not stale, f"stale baseline entries: {stale}"


# --------------------------------------------------------------------------
# kernel contract checker: broken specs
# --------------------------------------------------------------------------

def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_kernel_check_non_dividing_block():
    with pytest.raises(KernelContractError, match="does not divide"):
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((5, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=_sds((16, 8)),
            args=(np.zeros((16, 8), np.float32),))


def test_kernel_check_wrong_index_map_arity():
    with pytest.raises(KernelContractError, match="index_map takes"):
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=_sds((16, 8)),
            args=(np.zeros((16, 8), np.float32),))


def test_kernel_check_out_of_bounds_index_map():
    with pytest.raises(KernelContractError, match="out of bounds"):
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i + 1, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=_sds((16, 8)),
            args=(np.zeros((16, 8), np.float32),))


def test_kernel_check_uncovered_output():
    with pytest.raises(KernelContractError, match="never written"):
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=_sds((32, 8)),
            args=(np.zeros((32, 8), np.float32),))


def test_kernel_check_vmem_budget(monkeypatch):
    monkeypatch.setenv("REPRO_VMEM_BUDGET_BYTES", "64")
    with pytest.raises(KernelContractError, match="VMEM footprint"):
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
            out_shape=_sds((16, 8)),
            args=(np.zeros((16, 8), np.float32),))


def test_kernel_check_aggregates_all_violations():
    with pytest.raises(KernelContractError) as ei:
        kernel_check.check_launch(
            name="bad", grid=(2,),
            in_specs=[pl.BlockSpec((5, 8), lambda i, j: (i, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda i: (i + 1, 0)),
            out_shape=_sds((16, 8)),
            args=(np.zeros((16, 8), np.float32),))
    msg = str(ei.value)
    assert "does not divide" in msg
    assert "index_map takes" in msg
    assert "out of bounds" in msg


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def test_compat_shim_rejects_bad_launch():
    """A broken spec through the pallas_compat entry point fails before
    dispatch when checking is on."""
    from repro.kernels import pallas_compat as pc
    call = pc.pallas_call(
        _copy_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((5, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        out_shape=_sds((16, 8)),
        interpret=True)
    with checking(True), pytest.raises(KernelContractError):
        call(jnp.zeros((16, 8), jnp.float32))


def test_compat_shim_good_launch_roundtrips():
    from repro.kernels import pallas_compat as pc
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)),
                    jnp.float32)
    call = pc.pallas_call(
        _copy_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        out_shape=_sds((16, 8)),
        interpret=True)
    with checking(True):
        np.testing.assert_allclose(np.asarray(call(x)), np.asarray(x))


def test_checking_toggle_restores_state():
    before = kernel_check.kernel_check_enabled()
    with checking(not before):
        assert kernel_check.kernel_check_enabled() is (not before)
    assert kernel_check.kernel_check_enabled() is before


# --------------------------------------------------------------------------
# kernel contract checker: the real kernels pass
# --------------------------------------------------------------------------

def test_existing_kernels_pass_contract_check(rng):
    from repro.kernels.attention.mha import mha_forward
    from repro.kernels.decode.decode_attn import paged_decode_attention

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    with checking(True):
        out = mha_forward(arr(2, 16, 8), arr(2, 16, 8), arr(2, 16, 8),
                          block_q=8, block_k=8, interpret=True)
        assert out.shape == (2, 16, 8)
        pt = jnp.asarray(np.arange(1, 9, dtype=np.int32).reshape(2, 4))
        out = paged_decode_attention(
            arr(2, 1, 2, 8), arr(9, 4, 1, 8), arr(9, 4, 1, 8), pt,
            jnp.array([5, 9], jnp.int32), interpret=True)
        assert out.shape == (2, 1, 2, 8)


def test_paged_kernel_passes_under_jit(rng):
    """Scalar-prefetch operands are tracers under jit: the checker must
    skip (not guess) value-dependent checks and still pass."""
    from repro.kernels.decode.decode_attn import paged_decode_attention

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    fn = jax.jit(lambda q, k, v, pt, ln: paged_decode_attention(
        q, k, v, pt, ln, interpret=True))
    pt = jnp.asarray(np.arange(1, 9, dtype=np.int32).reshape(2, 4))
    with checking(True):
        out = fn(arr(2, 1, 2, 8), arr(9, 4, 1, 8), arr(9, 4, 1, 8), pt,
                 jnp.array([5, 9], jnp.int32))
    assert out.shape == (2, 1, 2, 8)


# --------------------------------------------------------------------------
# retrace guard
# --------------------------------------------------------------------------

class _Fake:
    def __init__(self):
        self.compilations = {"prefill": 1, "decode": 1}


def test_retrace_guard_fails_on_growth():
    f = _Fake()
    with pytest.raises(RetraceError, match="decode: 1 -> 2"):
        with retrace_guard(f, label="fake"):
            f.compilations["decode"] += 1


def test_retrace_guard_passes_when_quiet():
    f = _Fake()
    with retrace_guard(f):
        f.compilations["decode"] += 0


def test_retrace_guard_allow_tolerates_known_compiles():
    f = _Fake()
    with retrace_guard(f, allow=1):
        f.compilations["decode"] += 1


def test_retrace_guard_int_census():
    class C:
        compilations = 0

    c = C()
    with pytest.raises(RetraceError):
        with retrace_guard(c):
            c.compilations = 2


def test_retrace_guard_engine_cold_vs_warm():
    """The live invariant: a cold engine compiles inside the guard and
    fails; the same engine, warmed, serves a fresh batch guarded clean."""
    from repro.configs.base import get_config, shrink
    from repro.core.famous import FamousConfig
    from repro.models import module, transformer
    from repro.serve.engine import Request, ServingEngine

    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    eng = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                        n_slots=2, max_seq=32, chunk=8)

    def reqs(rid0):
        return [Request(rid=rid0 + i, max_new=3,
                        tokens=[1, 2, 3, 4, 5 + i]) for i in range(2)]

    with pytest.raises(RetraceError):
        with retrace_guard(eng, label="cold engine"):
            eng.run(reqs(0))
    with retrace_guard(eng, label="warm engine"):
        eng.run(reqs(10))

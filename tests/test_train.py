"""Training-substrate integration tests: loss decreases, microbatch
equivalence, checkpoint restart, fault injection, straggler watchdog,
elastic reshard, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SMOKE_SHAPES, get_config, shrink
from repro.core.famous import FamousConfig
from repro.data import pipeline
from repro.launch.mesh import make_mesh
from repro.optim import adamw, compression
from repro.train import checkpoint as ckpt_lib
from repro.train import elastic
from repro.train import step as step_lib
from repro.train import trainer as trainer_lib

CFG = shrink(get_config("famous-bert"))
SHAPE = SMOKE_SHAPES["smoke_train"]
FCFG = FamousConfig(impl="xla")


def _tcfg(**kw):
    base = dict(compute_dtype=jnp.float32, loss_chunk=16,
                optimizer=adamw.AdamWConfig(lr=1e-2),
                schedule_warmup=2, schedule_total=100)
    base.update(kw)
    return step_lib.TrainConfig(**base)


def _batch(step=0):
    return {k: jnp.asarray(v)
            for k, v in pipeline.host_batch(CFG, SHAPE, 0, step).items()}


def test_loss_decreases():
    tcfg = _tcfg()
    state = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    ts = jax.jit(step_lib.make_train_step(CFG, FCFG, tcfg))
    losses = []
    for i in range(25):
        state, m = ts(state, _batch(0))  # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_train_step_runs_with_pallas_impl():
    """impl="pallas" is trainable end-to-end: the step runs the Pallas
    forward + flash-backward kernels (interpret mode on CPU) and produces
    finite loss/gradients that match the XLA path."""
    tcfg = _tcfg()
    fcfg_pl = FamousConfig(impl="pallas", tile_q=32, tile_k=32, tile_d=64)
    s_pl = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    s_xla = jax.tree_util.tree_map(lambda x: x, s_pl)
    ts_pl = jax.jit(step_lib.make_train_step(CFG, fcfg_pl, tcfg))
    ts_xla = jax.jit(step_lib.make_train_step(CFG, FCFG, tcfg))
    b = _batch()
    s_pl, m_pl = ts_pl(s_pl, b)
    s_xla, m_xla = ts_xla(s_xla, b)
    assert np.isfinite(float(m_pl["loss"]))
    assert float(m_pl["grad_norm"]) > 0.0
    np.testing.assert_allclose(float(m_pl["loss"]), float(m_xla["loss"]),
                               rtol=1e-4)
    for a, b_ in zip(jax.tree_util.tree_leaves(s_pl["params"]),
                     jax.tree_util.tree_leaves(s_xla["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=1e-3)


def test_microbatch_grad_equivalence():
    """Accumulated microbatch gradients equal the single-batch gradients."""
    s1 = step_lib.init_state(CFG, _tcfg(), jax.random.PRNGKey(0))
    s2 = jax.tree_util.tree_map(lambda x: x, s1)
    ts1 = jax.jit(step_lib.make_train_step(CFG, FCFG, _tcfg()))
    ts2 = jax.jit(step_lib.make_train_step(CFG, FCFG, _tcfg(microbatches=2)))
    b = _batch()
    s1, m1 = ts1(s1, b)
    s2, m2 = ts2(s2, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b_ in zip(jax.tree_util.tree_leaves(s1["params"]),
                     jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    tcfg = _tcfg()
    state = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt_lib.save_checkpoint(d, 7, state)
    assert ckpt_lib.latest_step(d) == 7
    restored, step = ckpt_lib.restore_checkpoint(d, state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_k(tmp_path):
    d = str(tmp_path / "ck")
    state = {"x": jnp.arange(4.0), "step": jnp.int32(0)}
    for s in range(6):
        ckpt_lib.save_checkpoint(d, s, state, keep=3)
    assert ckpt_lib.all_steps(d) == [3, 4, 5]


def test_trainer_fault_injection_restores(tmp_path):
    """Inject failures at steps 5 and 9; the run completes with restarts."""
    tcfg = _tcfg()
    state = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    ts = jax.jit(step_lib.make_train_step(CFG, FCFG, tcfg))
    fired = set()

    def fault(step):
        if step in (5, 9) and step not in fired:
            fired.add(step)
            raise trainer_lib.InjectedFault(f"simulated node loss @ {step}")

    tr = trainer_lib.Trainer(
        ts, state, lambda s: _batch(s),
        trainer_lib.TrainerConfig(total_steps=12, ckpt_every=4,
                                  ckpt_dir=str(tmp_path / "ft")),
        fault_hook=fault)
    final = tr.run()
    assert int(final["step"]) == 12
    assert tr.restarts == 2
    assert len(tr.failures) == 2


def test_trainer_resume_from_checkpoint_is_exact(tmp_path):
    """Kill after step 6, restart: final params equal an uninterrupted run
    (deterministic data pipeline => exact replay)."""
    def fresh():
        tcfg = _tcfg()
        st = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
        return st, jax.jit(step_lib.make_train_step(CFG, FCFG, tcfg))

    # uninterrupted
    st, ts = fresh()
    for i in range(10):
        st, _ = ts(st, _batch(i))

    # interrupted at 6 + resumed via Trainer
    d = str(tmp_path / "resume")
    st2, ts2 = fresh()
    tr = trainer_lib.Trainer(
        ts2, st2, lambda s: _batch(s),
        trainer_lib.TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=d))
    tr.run()
    st3, ts3 = fresh()
    tr2 = trainer_lib.Trainer(
        ts3, st3, lambda s: _batch(s),
        trainer_lib.TrainerConfig(total_steps=10, ckpt_every=3, ckpt_dir=d))
    final = tr2.run()
    assert int(final["step"]) == 10
    for a, b in zip(jax.tree_util.tree_leaves(st["params"]),
                    jax.tree_util.tree_leaves(final["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_straggler_watchdog(tmp_path):
    import time
    tcfg = _tcfg()
    state = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    inner = jax.jit(step_lib.make_train_step(CFG, FCFG, tcfg))

    def slow_step(state, batch):
        if int(state["step"]) == 8:
            time.sleep(0.3)  # simulated straggler host
        return inner(state, batch)

    tr = trainer_lib.Trainer(
        slow_step, state, lambda s: _batch(s),
        trainer_lib.TrainerConfig(total_steps=12, ckpt_every=100,
                                  ckpt_dir=str(tmp_path / "st"),
                                  straggler_factor=5.0))
    tr.run()
    assert any(e.step == 8 for e in tr.straggler_events), tr.straggler_events


def test_elastic_reshard_restore(tmp_path):
    """Save on one device layout, restore onto a different mesh."""
    tcfg = _tcfg()
    state = step_lib.init_state(CFG, tcfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "el")
    ckpt_lib.save_checkpoint(d, 3, state)
    mesh = make_mesh((1, 1), ("data", "model"))
    restored, step = elastic.reshard_restore(
        d, state, mesh, step_lib.state_logical_axes(CFG))
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    probs = elastic.validate_resize({"pod": 2, "data": 16, "model": 16},
                                    {"pod": 4, "data": 16, "model": 16}, 256)
    assert probs == []
    probs = elastic.validate_resize({"data": 16, "model": 16},
                                    {"data": 8, "model": 32}, 256)
    assert len(probs) == 2


def test_gradient_compression_error_feedback():
    """Compressed psum over a 1-axis mesh: mean preserved within int8 noise;
    error feedback drives the *accumulated* bias to ~zero over steps."""
    from jax.sharding import PartitionSpec as P

    shard_map = getattr(jax, "shard_map", None)  # moved to jax.* in 0.5
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    mesh = make_mesh((1,), ("pod",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}

    @jax.jit
    def run(g):
        def inner(g):
            out, res = compression.compressed_psum_tree(g, mesh, "pod")
            return out, res
        return shard_map(inner, mesh=mesh, in_specs=({"w": P()},),
                         out_specs=({"w": P()}, {"w": P()}))(g)

    out, res = run(g)
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(out["w"] - g["w"]).max()) <= scale * 0.51
    # residual holds exactly the quantization error
    np.testing.assert_allclose(np.asarray(g["w"] - out["w"]),
                               np.asarray(res["w"]), atol=1e-6)


def test_compress_decompress_roundtrip():
    g = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 3.0
    q, scale, resid = compression.compress(g)
    np.testing.assert_allclose(np.asarray(compression.decompress(q, scale)
                                          + resid), np.asarray(g), atol=1e-6)

"""FAMOUS core behaviour: the paper's invariants.

* Algorithm 1 tiling invariance: the TS-tiled projection equals the untiled
  one for every tile size (the paper's accumulation correctness).
* impl agreement: reference / xla / pallas produce the same attention.
* runtime programmability: one compiled FlexibleAttention program serves
  smaller (h, SL, dh) topologies exactly (tests #1–#8 of Table I).
* analytical model (paper §VII): latency decreases with larger tiles
  (Table I tests #9–#10) and the TS sweep reproduces the paper's trend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytical, famous, flexible, quant


def _qkv_inputs(B=2, S=64, D=128, H=4, KV=2, dh=32):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (B, S, D)) * 0.5
    wq = jax.random.normal(ks[1], (D, H, dh)) * 0.05
    wk = jax.random.normal(ks[2], (D, KV, dh)) * 0.05
    wv = jax.random.normal(ks[3], (D, KV, dh)) * 0.05
    return x, wq, wk, wv


@pytest.mark.parametrize("tile_d", [16, 32, 64, 128])
def test_algorithm1_tiling_invariance(tile_d):
    x, wq, wk, wv = _qkv_inputs()
    q0, k0, v0 = famous.qkv_projection_xla(x, wq, wk, wv)
    q1, k1, v1 = famous.qkv_projection_reference(x, wq, wk, wv, tile_d=tile_d)
    for a, b in [(q0, q1), (k0, k1), (v0, v1)]:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("impl", ["reference", "xla", "pallas"])
@pytest.mark.parametrize("causal", [True, False])
def test_impl_agreement(impl, causal):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32)) * 0.5
    k = jax.random.normal(ks[1], (2, 256, 2, 32)) * 0.5
    v = jax.random.normal(ks[2], (2, 256, 2, 32)) * 0.5
    ref = famous.attention_reference(q, k, v, causal=causal)
    cfg = famous.FamousConfig(impl=impl, tile_q=128, tile_k=128)
    out = famous.attention(q, k, v, causal=causal, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flexible_attention_one_executable_many_topologies():
    """Paper §IV-C: vary h / SL / d_head at runtime without recompiling."""
    fa = flexible.FlexibleAttention(max_heads=8, max_seq=128, max_head_dim=64,
                                    causal=True)
    for (H, S, dh) in [(8, 128, 64), (4, 128, 64), (2, 64, 64), (8, 128, 32),
                       (3, 96, 16)]:
        ks = jax.random.split(jax.random.PRNGKey(S + H + dh), 3)
        q = jax.random.normal(ks[0], (2, S, H, dh)) * 0.5
        k = jax.random.normal(ks[1], (2, S, H, dh)) * 0.5
        v = jax.random.normal(ks[2], (2, S, H, dh)) * 0.5
        out = fa(q, k, v)
        ref = famous.attention_reference(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, err_msg=f"{(H, S, dh)}")
    # one executable: jit cache of fa._fn has exactly one entry
    assert fa._fn._cache_size() == 1


def test_flexible_attention_counts_compilations():
    """The compilations counter tracks actual (re)traces: one executable
    reused across topologies => exactly one compilation."""
    fa = flexible.FlexibleAttention(max_heads=4, max_seq=64, max_head_dim=32)
    assert fa.compilations == 0
    for (H, S, dh) in [(4, 64, 32), (2, 32, 16), (3, 48, 32)]:
        ks = jax.random.split(jax.random.PRNGKey(H + S), 3)
        qkv = [jax.random.normal(k, (1, S, H, dh)) * 0.5 for k in ks]
        fa(*qkv)
    assert fa.compilations == 1


@pytest.mark.parametrize("n,expect", [
    (1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (127, 128), (128, 128),
    (129, 256),
])
def test_next_pow2(n, expect):
    assert flexible.next_pow2(n) == expect


def test_decode_attention_masks_by_cache_len():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16)) * 0.5
    kc = jax.random.normal(ks[1], (2, 32, 4, 16)) * 0.5
    vc = jax.random.normal(ks[2], (2, 32, 4, 16)) * 0.5
    clen = jnp.array([5, 32], jnp.int32)
    out = famous.decode_attention(q, kc, vc, clen)
    # manual: attend only to the first clen entries
    ref0 = famous.attention_reference(q[:1], kc[:1, :5], vc[:1, :5],
                                      causal=False)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref0[0]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# analytical model (§VII)
# ---------------------------------------------------------------------------

def test_analytical_latency_tile_trend():
    """Table I tests #9-#10: smaller tiles -> more reload iterations ->
    higher latency. The TPU model must reproduce the trend."""
    lats = []
    for ts in (128, 256, 512):
        lat = analytical.mha_latency(batch=1, seq=4096, heads=16, kv_heads=16,
                                     head_dim=128, d_model=2048,
                                     tile_q=ts, tile_k=ts, tile_d=ts)
        lats.append(lat.total)
    assert lats[0] >= lats[1] >= lats[2], lats


def test_analytical_flops_match_paper_gop():
    """The model's FLOP count equals the paper's GOP definition."""
    seq, d_model, heads = 64, 768, 8
    lat = analytical.mha_latency(batch=1, seq=seq, heads=heads,
                                 kv_heads=heads, head_dim=d_model // heads,
                                 d_model=d_model, tile_q=64, tile_k=64,
                                 tile_d=64)
    paper = analytical.paper_gops(seq=seq, d_model=d_model, heads=heads)
    # model adds softmax VPU flops; matmul part must match exactly
    matmul_flops = sum(
        m.flops for m in lat.modules) - 6.0 * heads * seq * seq
    assert abs(matmul_flops - paper * 1e9) / (paper * 1e9) < 0.01


def test_autotuner_respects_vmem():
    res = analytical.autotune_tiles(batch=1, seq=8192, heads=8, kv_heads=8,
                                    head_dim=128, d_model=1024)
    assert analytical.fits_vmem(res["latency"])
    tiles = res["tiles"]
    assert all(t % 128 == 0 for t in tiles.values())  # MXU-aligned


# ---------------------------------------------------------------------------
# 8-bit quantization (paper's fixed point)
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 128))
    q, s = quant.quantize(x, axis=-1)
    err = jnp.abs(quant.dequantize(q, s) - x)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float((err <= amax / 127.0 * 0.5 + 1e-6).mean()) == 1.0


def test_int8_einsum_close_to_f32():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 32, 64))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 4, 16)) * 0.05
    out8 = quant.int8_einsum("...sd,dhe->...she", x, w, out_dtype=jnp.float32)
    ref = jnp.einsum("bsd,dhe->bshe", x, w)
    rel = float(jnp.abs(out8 - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 0.05, rel

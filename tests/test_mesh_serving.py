"""Mesh-sharded serving parity: a TP engine must be *token-identical* to
the unsharded baseline across cache kinds, kernel impls and architectures.

The suite runs single-device by default (conftest sets no XLA_FLAGS), so
only the TP=1 bit-identity test executes; the TP>=2 matrix skips unless
the process was started with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
`== multi-device ==` stage in scripts/ci.sh does exactly that).

The attn config overrides the smoke shrink to 8 heads / 4 kv heads so
TP=4 genuinely shards the KV dim; the hybrid (recurrentgemma) config
keeps its 1 kv head, which exercises the spec_for_axes replicate-fallback
live (kv replicated, heads + FFN hidden still sharded).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace_guard import retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.launch.mesh import make_serving_mesh
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine


def _need(tp):
    if jax.device_count() < tp:
        pytest.skip(f"needs {tp} devices (run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=8)")


_CFGS = {
    # shrink() default kv=2 would not divide TP=4 — force 8H/4KV
    "attn": dict(name="qwen2-7b", over=dict(num_heads=8, num_kv_heads=4,
                                            head_dim=8)),
    "hybrid": dict(name="recurrentgemma-2b", over={}),
}
_STATE: dict = {}


def _cfg_params(arch):
    if arch not in _STATE:
        spec = _CFGS[arch]
        cfg = shrink(get_config(spec["name"]), **spec["over"])
        params = module.init_params(transformer.model_spec(cfg),
                                    jax.random.PRNGKey(0), jnp.float32)
        _STATE[arch] = (cfg, params)
    return _STATE[arch]


def _reqs(cfg, sampled=False):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(3):
        r = Request(rid=i, max_new=5,
                    tokens=list(rng.integers(0, cfg.vocab_size, 5 + 3 * i)))
        if sampled:
            r.temperature, r.top_k, r.seed = 0.8, 8, 123 + i
        reqs.append(r)
    return reqs


def _run(arch, mesh=None, impl="xla", cache_kind="contiguous",
         sampled=False, **kw):
    cfg, params = _cfg_params(arch)
    with warnings.catch_warnings():
        # hybrid kv=1 on a TP mesh replicates with a RuntimeWarning — that
        # fallback path is intentional here, not a failure
        warnings.simplefilter("ignore", RuntimeWarning)
        eng = ServingEngine(params, cfg, FamousConfig(impl=impl), n_slots=2,
                            max_seq=32, chunk=8, cache_kind=cache_kind,
                            page_size=8, mesh=mesh, **kw)
        done = eng.run(_reqs(cfg, sampled))
    assert all(r.error is None for r in done), [r.error for r in done]
    return {r.rid: tuple(r.out) for r in done}, eng


_BASE: dict = {}


def _baseline(arch, impl="xla", cache_kind="contiguous", sampled=False, **kw):
    key = (arch, impl, cache_kind, sampled, tuple(sorted(kw)))
    if key not in _BASE:
        _BASE[key] = _run(arch, None, impl, cache_kind, sampled, **kw)[0]
    return _BASE[key]


def test_tp1_mesh_bit_identical():
    """mesh on 1 device must change nothing: same tokens, bitwise-equal
    final caches, same census (runs in the plain single-device suite)."""
    base_outs, base_eng = _run("attn")
    outs, eng = _run("attn", mesh=make_serving_mesh(tp=1))
    assert outs == base_outs
    for a, b in zip(jax.tree_util.tree_leaves(base_eng.caches),
                    jax.tree_util.tree_leaves(eng.caches)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert eng.compilations == base_eng.compilations
    assert eng.cache_bytes_per_device() == base_eng.cache_bytes_per_device()


@pytest.mark.parametrize("arch", ["attn", "hybrid"])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("cache_kind", ["contiguous", "paged"])
@pytest.mark.parametrize("tp", [2, 4])
def test_tp_parity_matrix(tp, cache_kind, impl, arch):
    _need(tp)
    base = _baseline(arch, impl, cache_kind)
    outs, eng = _run(arch, make_serving_mesh(tp=tp), impl, cache_kind)
    assert outs == base
    # census identical to the unsharded engine: sharding must not fork
    # executables (retrace_guard's O(1)-compilations contract)
    assert eng.compilations["prefill"] == 1
    assert eng.compilations["decode"] == 1


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_parity_seeded_sampling(tp):
    _need(tp)
    base = _baseline("attn", cache_kind="paged", sampled=True)
    outs, _ = _run("attn", make_serving_mesh(tp=tp), cache_kind="paged",
                   sampled=True)
    assert outs == base


@pytest.mark.parametrize("tp", [2])
def test_tp_prefix_cache_and_speculative(tp):
    """The host-side allocator / prefix index / drafter are device-agnostic:
    with both on, a TP engine stays token-identical and the allocator
    invariants hold after the drain."""
    _need(tp)
    kw = dict(cache_kind="paged", prefix_cache=True, speculative=True,
              draft_k=3)
    base = _baseline("attn", **kw)
    outs, eng = _run("attn", mesh=make_serving_mesh(tp=tp), **kw)
    assert outs == base
    eng.alloc.assert_invariants()
    assert eng.speculative_active


@pytest.mark.parametrize("tp", [2])
def test_tp_retrace_guard(tp):
    """A warmed sharded engine serves a fresh batch with zero new
    compilations — out_shardings must not introduce retraces."""
    _need(tp)
    cfg, params = _cfg_params("attn")
    eng = ServingEngine(params, cfg, FamousConfig(impl="xla"), n_slots=2,
                        max_seq=32, chunk=8, cache_kind="paged", page_size=8,
                        mesh=make_serving_mesh(tp=tp))
    eng.run(_reqs(cfg))
    rng = np.random.default_rng(7)
    fresh = [Request(rid=100 + i, max_new=4,
                     tokens=list(rng.integers(0, cfg.vocab_size, 4 + i)))
             for i in range(3)]
    with retrace_guard(eng, label="warm TP engine"):
        eng.run(fresh)


@pytest.mark.parametrize("tp", [2, 4])
@pytest.mark.parametrize("cache_kind", ["contiguous", "paged"])
def test_cache_bytes_per_device_shrink(tp, cache_kind):
    """The KV bytes resident per device must be exactly 1/TP of the
    unsharded engine's (the attn config's caches are all kv-head-sharded
    leaves, so the ratio is exact, not approximate)."""
    _need(tp)
    _, base_eng = _run("attn", cache_kind=cache_kind)
    _, eng = _run("attn", make_serving_mesh(tp=tp), cache_kind=cache_kind)
    assert eng.cache_bytes_per_device() * tp == base_eng.cache_bytes_per_device()

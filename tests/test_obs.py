"""Observability subsystem: metrics registry, tracer, and the Observer
seam through the serving engine.

Four layers of gate:

  * **Instruments** — the log-bucket histogram's quantiles against a
    numpy reference (within the documented ~12% bucket resolution),
    counter/gauge/label plumbing, and a golden Prometheus text
    exposition checked byte-for-byte plus through the format validator
    (which itself is tested against deliberately malformed dumps).
  * **Tracer** — Chrome/Perfetto ``trace_event`` schema validity,
    balanced begin/end nesting, slot/rid attribution on instants, and
    bounded-buffer overflow accounting.
  * **Observer-through-engine** — observer-on output token-identical to
    observer-off (observability must never change scheduling or
    sampling), metric coverage on real runs: prefix-cache warm hits,
    speculation counters agreeing with the engine's own ledger, census
    export, TTFT/TPOT sample counts matching retirements.
  * **Clock unification** — the serving stack has exactly ONE
    ``time.*`` call site (``repro.obs.trace.now``), and every
    ``Request.t_*`` mark falls inside a ``now()``-bracketed run.
"""
import functools
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace_guard import census, retrace_guard
from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.obs.metrics import (LOG_BUCKETS, Histogram, MetricsRegistry,
                               log_buckets, validate_prometheus_text)
from repro.obs.runtime import NULL_OBSERVER, NullObserver, Observer
from repro.obs.trace import Tracer, now
from repro.serve.engine import Request, ServingEngine

MAX_SEQ = 32
CHUNK = 8


@functools.lru_cache(maxsize=None)
def _cfg_params():
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _requests(cfg, n=6, seed=0, max_new=4, shared_head=0, rid0=0):
    rng = np.random.default_rng(seed)
    head = list(rng.integers(0, cfg.vocab_size, size=shared_head))
    return [Request(rid=rid0 + i,
                    tokens=head + list(rng.integers(
                        0, cfg.vocab_size,
                        size=int(rng.integers(2, 10)))),
                    max_new=max_new)
            for i in range(n)]


def _engine(observer=None, **kw):
    cfg, params = _cfg_params()
    return ServingEngine(params, cfg, FamousConfig(impl="xla"),
                         n_slots=2, max_seq=MAX_SEQ, chunk=CHUNK,
                         observer=observer, **kw)


# ---------------------------------------------------------------------------
# histogram vs numpy reference
# ---------------------------------------------------------------------------


def test_log_buckets_schema():
    b = log_buckets(1e-2, 1e2, per_decade=10)
    assert len(b) == 41
    assert b[0] == pytest.approx(1e-2) and b[-1] == pytest.approx(1e2)
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** 0.1) for r in ratios)
    # the default schema really is 20/decade over ten decades
    assert len(LOG_BUCKETS) == 201
    assert LOG_BUCKETS[0] == pytest.approx(1e-5)
    assert LOG_BUCKETS[-1] == pytest.approx(1e5)


def test_histogram_quantiles_match_numpy_within_bucket_resolution():
    rng = np.random.default_rng(3)
    for scale in (1e-3, 1.0, 50.0):
        values = rng.lognormal(mean=math.log(scale), sigma=1.0, size=2000)
        h = Histogram.of(values)
        assert h.count() == 2000
        assert h.sum() == pytest.approx(values.sum())
        for q in (5, 25, 50, 75, 95, 99):
            ref = float(np.percentile(values, q))
            got = h.percentile(q)
            # one log bucket is a 10^(1/20) ~ 12.2% span; interpolation
            # keeps the estimate inside the containing bucket
            assert ref / 1.13 <= got <= ref * 1.13, (scale, q, ref, got)


def test_histogram_edge_cases():
    h = Histogram("h", "h")
    assert math.isnan(h.quantile(0.5))
    h.observe(1e9)              # beyond the last bound -> +Inf bucket
    assert h.count() == 1
    assert h.quantile(0.5) == pytest.approx(LOG_BUCKETS[-1])  # clamped
    h2 = Histogram("h2", "h2", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 3.0):
        h2.observe(v)
    assert h2.count() == 4 and h2.sum() == pytest.approx(8.0)
    assert 0.0 < h2.quantile(0.1) <= 1.0
    assert 2.0 < h2.quantile(0.9) <= 4.0
    # labelled cells are independent
    h3 = Histogram("h3", "h3", ("phase",), buckets=(1.0,))
    h3.observe(0.5, phase="a")
    assert h3.count(phase="a") == 1 and h3.count(phase="b") == 0


# ---------------------------------------------------------------------------
# Prometheus exposition: golden render + validator
# ---------------------------------------------------------------------------


def _golden_registry():
    reg = MetricsRegistry()
    reg.counter("t_reqs_total", "requests served", ("status",)) \
        .inc(3, status='o"k')
    reg.gauge("t_depth", "queue depth").set(1.5)
    h = reg.histogram("t_lat_seconds", "latency", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(3.0)
    return reg


GOLDEN = """\
# HELP t_depth queue depth
# TYPE t_depth gauge
t_depth 1.5
# HELP t_lat_seconds latency
# TYPE t_lat_seconds histogram
t_lat_seconds_bucket{le="1"} 1
t_lat_seconds_bucket{le="2"} 1
t_lat_seconds_bucket{le="+Inf"} 2
t_lat_seconds_sum 3.5
t_lat_seconds_count 2
# HELP t_reqs_total requests served
# TYPE t_reqs_total counter
t_reqs_total{status="o\\"k"} 3
"""


def test_prometheus_exposition_golden():
    assert _golden_registry().prometheus_text() == GOLDEN


def test_validator_accepts_and_counts_samples():
    assert validate_prometheus_text(GOLDEN) == 7
    # a full default-schema registry validates too
    reg = MetricsRegistry()
    h = reg.histogram("big_seconds", "h", ("phase",))
    for i in range(50):
        h.observe(10.0 ** (i % 7 - 3), phase="decode")
    assert validate_prometheus_text(reg.prometheus_text()) \
        == len(LOG_BUCKETS) + 3


@pytest.mark.parametrize("bad", [
    "no_type_line 1\n",
    "# TYPE x wat\nx 1\n",
    "# TYPE x counter\nx{unclosed 1\n",
    "# TYPE x counter\nx notafloat\n",
    # non-cumulative buckets
    "# TYPE h histogram\n"
    'h_bucket{le="1"} 5\nh_bucket{le="2"} 3\nh_bucket{le="+Inf"} 5\n'
    "h_count 5\n",
    # missing +Inf bucket
    '# TYPE h histogram\nh_bucket{le="1"} 1\nh_count 1\n',
    # _count disagrees with the +Inf bucket
    "# TYPE h histogram\n"
    'h_bucket{le="+Inf"} 2\nh_sum 1\nh_count 3\n',
    # bucket without an le label
    "# TYPE h histogram\nh_bucket 2\nh_count 2\n",
])
def test_validator_rejects_malformed(bad):
    with pytest.raises(ValueError):
        validate_prometheus_text(bad)


def test_label_escaping_survives_the_validator():
    reg = MetricsRegistry()
    reg.counter("esc_total", "c", ("k",)).inc(1, k='a\\b"c\nd')
    text = reg.prometheus_text()
    assert validate_prometheus_text(text) == 1
    assert '\\\\' in text and '\\"' in text and "\\n" in text


def test_registry_idempotent_and_schema_checked():
    reg = MetricsRegistry()
    c1 = reg.counter("c_total", "help", ("a",))
    assert reg.counter("c_total", "help", ("a",)) is c1
    with pytest.raises(AssertionError):
        reg.counter("c_total", "help", ("b",))   # different labels
    with pytest.raises(AssertionError):
        reg.gauge("c_total", "help", ("a",))     # different kind
    c1.inc(2, a="x")
    assert reg.snapshot() == {'c_total{a="x"}': 2.0}


# ---------------------------------------------------------------------------
# tracer: schema, nesting, attribution, bounded buffer
# ---------------------------------------------------------------------------


def test_tracer_schema_and_nesting():
    tr = Tracer()
    tr.begin("decode", step=1, slots=2)
    tr.instant("admit", rid=7, slot=1)
    assert not tr.balanced
    tr.end("decode", step=1)
    assert tr.balanced
    doc = json.loads(json.dumps(tr.to_json()))   # JSON-serialisable
    evs = doc["traceEvents"]
    assert [e["ph"] for e in evs] == ["B", "i", "E"]
    for e in evs:
        assert set(e) >= {"name", "ph", "ts", "pid", "tid", "args"}
        assert isinstance(e["ts"], float) and e["ts"] >= 0.0
    assert evs[0]["ts"] <= evs[1]["ts"] <= evs[2]["ts"]
    assert evs[1]["args"] == {"rid": 7, "slot": 1}   # attribution survives
    assert evs[1]["s"] == "t"
    assert doc["otherData"]["dropped"] == 0


def test_tracer_bounded_buffer_drops_and_counts():
    tr = Tracer(limit=3)
    for i in range(5):
        tr.instant("x", i=i)
    assert len(tr.events) == 3 and tr.dropped == 2
    assert tr.to_json()["otherData"]["dropped"] == 2


def test_tracer_write(tmp_path):
    tr = Tracer()
    with_observer = Observer(trace=True)
    assert with_observer.tracer is not None
    tr.begin("p")
    tr.end("p")
    path = tmp_path / "trace.json"
    tr.write(str(path))
    assert len(json.loads(path.read_text())["traceEvents"]) == 2


# ---------------------------------------------------------------------------
# observer through the engine
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _paired_runs():
    """One observer-off and one observer-on engine over the same
    workload; cached so every assertion below shares the two runs."""
    cfg, _ = _cfg_params()
    obs = Observer(trace=True)
    off = _engine(observer=None, cache_kind="paged", page_size=8)
    on = _engine(observer=obs, cache_kind="paged", page_size=8)
    done_off = sorted(off.run(_requests(cfg)), key=lambda r: r.rid)
    done_on = sorted(on.run(_requests(cfg)), key=lambda r: r.rid)
    return done_off, done_on, obs, on


def test_observer_on_is_token_identical_to_off():
    done_off, done_on, _, _ = _paired_runs()
    assert [r.out for r in done_on] == [r.out for r in done_off]
    assert [r.error for r in done_on] == [r.error for r in done_off]


def test_observer_metric_coverage():
    _, done_on, obs, eng = _paired_runs()
    m = obs.metrics
    tok = sum(len(r.out) for r in done_on)
    assert m.get("repro_tokens_generated_total").value() == tok
    assert m.get("repro_requests_enqueued_total").value() == len(done_on)
    assert m.get("repro_requests_admitted_total").value() >= len(done_on)
    assert m.get("repro_requests_retired_total").value(status="ok") \
        == len(done_on)
    assert m.get("repro_engine_steps_total").value() > 0
    # every retirement with a first token contributes one TTFT sample
    assert m.get("repro_request_ttft_seconds").count() == len(done_on)
    assert m.get("repro_step_phase_seconds").count(phase="decode") > 0
    assert m.get("repro_step_phase_seconds").count(phase="prefill_chunk") > 0
    # paged engine: pages were grown and freed back
    assert m.get("repro_pages_total").value(op="grow") > 0
    assert m.get("repro_pages_total").value(op="free") > 0
    # the whole dump passes the format checker
    assert validate_prometheus_text(obs.prometheus_text()) > 100


def test_observer_trace_attribution_and_balance():
    _, done_on, obs, _ = _paired_runs()
    tr = obs.tracer
    assert tr.balanced and tr.events
    names = {e["name"] for e in tr.events}
    assert {"admit", "retire", "decode", "prefill_chunk"} <= names
    rids = {e["args"]["rid"] for e in tr.events if e["name"] == "retire"}
    assert rids == {r.rid for r in done_on}
    admits = [e for e in tr.events if e["name"] == "admit"]
    assert all(e["args"]["slot"] in (0, 1) for e in admits)
    # B/E pairs nest: depth never goes negative, ends at zero
    depth = 0
    for e in tr.events:
        depth += {"B": 1, "E": -1}.get(e["ph"], 0)
        assert depth >= 0
    assert depth == 0
    validate_json = json.dumps(obs.trace_json())
    assert json.loads(validate_json)["traceEvents"]


def test_observer_census_and_retrace_guard_sources():
    _, _, obs, eng = _paired_runs()
    assert obs.census() == {k: int(v) for k, v in eng.compilations.items()}
    # retrace_guard reads the census through the Observer...
    assert census(obs) == census(eng)
    # ...and out of a flat registry snapshot
    snap = obs.snapshot()
    assert census(snap) == census(eng)
    assert snap['repro_engine_compilations{exec="decode"}'] \
        == eng.compilations["decode"]
    # a guard over a warm engine, subject = the Observer, stays quiet
    cfg, _ = _cfg_params()
    with retrace_guard(obs, label="warm rerun via observer"):
        eng.run(_requests(cfg, seed=5, rid0=100))
    # a snapshot with no census gauges is an empty census, not garbage
    assert census({"repro_tokens_generated_total": 5.0,
                   'repro_pages_total{op="grow"}': 2.0}) == {}


def test_observer_prefix_cache_hit_counters():
    cfg, _ = _cfg_params()
    obs = Observer()
    eng = _engine(observer=obs, cache_kind="paged", page_size=8,
                  prefix_cache=True)
    shared = 16   # two full pages of shared head
    eng.run(_requests(cfg, seed=11, shared_head=shared))
    hits0 = obs.metrics.get("repro_prefix_lookups_total").value(result="hit")
    eng.run(_requests(cfg, seed=12, shared_head=shared, rid0=50))
    m = obs.metrics
    assert m.get("repro_prefix_lookups_total").value(result="hit") > hits0
    assert m.get("repro_prefix_pages_saved_total").value() \
        == eng.prefix_hit_pages
    assert m.get("repro_prefix_tokens_saved_total").value() \
        == eng.prefix_hit_tokens
    assert m.get("repro_pages_total").value(op="publish") > 0


def test_observer_speculation_counters_match_engine_ledger():
    cfg, _ = _cfg_params()
    obs = Observer()
    eng = _engine(observer=obs, speculative=True, draft_k=4)
    rng = np.random.default_rng(2)
    motif = list(map(int, rng.integers(0, cfg.vocab_size, 3)))
    reqs = [Request(rid=i, tokens=(motif * 8)[:10], max_new=8)
            for i in range(4)]
    eng.run(reqs)
    m = obs.metrics
    assert m.get("repro_spec_verify_steps_total").value() == eng.spec_steps
    assert m.get("repro_spec_drafted_total").value() == eng.spec_drafted
    assert m.get("repro_spec_accepted_total").value() == eng.spec_accepted
    assert eng.spec_drafted > 0
    drafted = m.get("repro_spec_drafted_total").value()
    accepted = m.get("repro_spec_accepted_total").value()
    assert accepted / max(drafted, 1) == pytest.approx(eng.acceptance_rate)
    lk = m.get("repro_draft_lookups_total")
    assert lk.value(result="hit") + lk.value(result="miss") > 0
    assert m.get("repro_draft_proposed_tokens_total").value() >= drafted


def test_null_observer_is_inert_and_complete():
    # NullObserver mirrors every public hook of Observer (a new hook
    # must be added to both or engines crash with observer=None)
    hooks = [n for n in dir(Observer) if n.startswith(("on_", "phase"))]
    for n in hooks:
        assert callable(getattr(NullObserver, n, None)), n
    NULL_OBSERVER.on_step(queue_depth=1, occupied=2)
    NULL_OBSERVER.on_tokens(5)
    with NULL_OBSERVER.phase("decode", slots=1):
        pass
    assert NULL_OBSERVER.census() == {}


# ---------------------------------------------------------------------------
# clock unification
# ---------------------------------------------------------------------------


def test_serving_stack_has_one_clock_call_site():
    """``repro.obs.trace.now`` is the serving stack's only ``time.*``
    call site: request marks, trace timestamps, launcher and bench
    timings all read one clock."""
    root = os.path.join(os.path.dirname(__file__), "..")
    offenders = []
    scan = ["src/repro/serve", "src/repro/obs", "src/repro/launch/serve.py",
            "benchmarks/serving_bench.py", "examples/serve_lm.py"]
    for rel in scan:
        path = os.path.join(root, rel)
        files = ([os.path.join(path, f) for f in os.listdir(path)
                  if f.endswith(".py")] if os.path.isdir(path) else [path])
        for f in files:
            if f.endswith(os.path.join("obs", "trace.py")):
                continue
            src = open(f, encoding="utf-8").read()
            if "time.monotonic(" in src or "time.perf_counter(" in src \
                    or "time.time(" in src:
                offenders.append(os.path.relpath(f, root))
    assert not offenders, f"direct clock calls outside obs.trace: {offenders}"


def test_request_marks_come_from_the_shared_clock():
    cfg, _ = _cfg_params()
    eng = _engine()
    t0 = now()
    done = eng.run(_requests(cfg, n=3, seed=21))
    t1 = now()
    for r in done:
        assert t0 <= r.t_submit <= r.t_first <= r.t_done <= t1, \
            (r.rid, r.t_submit, r.t_first, r.t_done, t0, t1)

"""Paged KV cache: allocator invariants, page-table kernel parity against
the gather-based reference, and token-identical paged-vs-contiguous serving
on mixed-length request batches (including slot reuse and pool exhaustion)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, shrink
from repro.core import famous
from repro.core.famous import FamousConfig
from repro.kernels.decode import decode_attn, ref as dec_ref
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine
from repro.serve.paged import (NULL_PAGE, PageAllocator, PagedCacheConfig,
                               PagePoolExhausted)

FCFG = FamousConfig(impl="xla")


def _params(cfg):
    return module.init_params(transformer.model_spec(cfg),
                              jax.random.PRNGKey(0), jnp.float32)


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_allocator_invariants():
    cfg = PagedCacheConfig(page_size=4, n_pages=9)  # 8 allocatable
    alloc = PageAllocator(cfg, n_slots=3, max_seq=16)
    alloc.grow(0, 5)   # 2 pages
    alloc.grow(1, 9)   # 3 pages
    alloc.grow(0, 7)   # still 2 pages — idempotent
    assert alloc.pages_held(0) == 2 and alloc.pages_held(1) == 3
    assert alloc.free_pages == 3
    live = [int(p) for s in (0, 1) for p in
            alloc.page_table[s, :alloc.pages_held(s)]]
    assert NULL_PAGE not in live            # null page never handed out
    assert len(set(live)) == len(live)      # no page aliased across slots
    alloc.free(0)
    assert alloc.free_pages == 5
    assert (alloc.page_table[0] == NULL_PAGE).all()


def test_allocator_exhaustion_leaves_state_untouched():
    cfg = PagedCacheConfig(page_size=4, n_pages=4)  # 3 allocatable
    alloc = PageAllocator(cfg, n_slots=2, max_seq=16)
    alloc.grow(0, 8)  # 2 pages
    table_before = alloc.page_table.copy()
    with pytest.raises(PagePoolExhausted):
        alloc.grow(1, 12)  # needs 3, only 1 free
    assert alloc.free_pages == 1
    assert (alloc.page_table == table_before).all()
    alloc.grow(1, 4)  # the last page is still allocatable


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------


def test_paged_kernel_matches_gather_reference():
    B, KV, group, dh = 3, 2, 4, 16
    ps, n_pages, n_p = 8, 17, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, KV, group, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, dh), jnp.float32)
    rng = np.random.default_rng(0)
    pt = jnp.asarray(rng.integers(1, n_pages, size=(B, n_p)), jnp.int32)
    lens = jnp.asarray([5, 23, 32], jnp.int32)
    out = decode_attn.paged_decode_attention(q, kp, vp, pt, lens,
                                             interpret=True)
    want = dec_ref.paged_decode_reference(q, kp, vp, pt, lens)
    np.testing.assert_allclose(out, want, atol=1e-5)


def test_chunk_prefill_kernel_matches_reference():
    """Contiguous chunked-prefill kernel vs the dense oracle, across
    offsets (first / middle / last chunk of a prompt)."""
    B, C, H, KV, dh = 2, 8, 4, 2, 16
    Skv = 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Skv, KV, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Skv, KV, dh), jnp.float32)
    from repro.kernels.decode import ops as dec_ops
    for off in (0, 8, 24):
        out = dec_ops.chunk_prefill_attention(q, kc, vc, jnp.int32(off),
                                              block_k=16, interpret=True)
        want = dec_ref.chunk_prefill_reference(q, kc, vc, jnp.int32(off))
        np.testing.assert_allclose(out, want, atol=1e-5)


def test_paged_chunk_prefill_kernel_matches_reference():
    """Scalar-prefetched page-table chunked-prefill kernel vs the
    gather-based oracle."""
    B, C, H, KV, dh = 2, 8, 4, 2, 16
    ps, n_p = 8, 8
    n_pages = 1 + B * n_p
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, ps, KV, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, ps, KV, dh), jnp.float32)
    rng = np.random.default_rng(4)
    pt = jnp.asarray(
        rng.permutation(np.arange(1, n_pages)).reshape(B, n_p), jnp.int32)
    from repro.kernels.decode import ops as dec_ops
    for off in (0, 8, 21):
        out = dec_ops.paged_chunk_prefill_attention(q, kp, vp, pt,
                                                    jnp.int32(off),
                                                    interpret=True)
        want = dec_ref.paged_chunk_prefill_reference(q, kp, vp, pt,
                                                     jnp.int32(off))
        np.testing.assert_allclose(out, want, atol=1e-5)


def _quantized_pools(key, n_pages, ps, KV, dh):
    """An fp pool plus its symmetric per-token-per-head int8 quantization."""
    from repro.core import quant as quant_lib
    pool = jax.random.normal(key, (n_pages, ps, KV, dh), jnp.float32)
    q, s = quant_lib.quantize(pool, axis=-1)
    return pool, q, s[..., 0].astype(jnp.float32)


def test_paged_int8_decode_kernel_matches_oracle():
    """Int8 paged decode: Pallas in-kernel dequant vs the XLA
    dequantizing-gather oracle (tight), and both vs the fp kernel on the
    pre-quantization pool (lossy but bounded drift)."""
    B, KV, group, dh = 3, 2, 4, 16
    ps, n_pages, n_p = 8, 17, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, KV, group, dh), jnp.float32)
    kf, kq, kscale = _quantized_pools(ks[1], n_pages, ps, KV, dh)
    vf, vq, vscale = _quantized_pools(ks[2], n_pages, ps, KV, dh)
    rng = np.random.default_rng(7)
    pt = jnp.asarray(rng.integers(1, n_pages, size=(B, n_p)), jnp.int32)
    lens = jnp.asarray([5, 23, 32], jnp.int32)
    out = decode_attn.paged_decode_attention_int8(q, kq, vq, kscale, vscale,
                                                  pt, lens, interpret=True)
    want = dec_ref.paged_decode_reference_int8(q, kq, vq, kscale, vscale,
                                               pt, lens)
    np.testing.assert_allclose(out, want, atol=1e-5)
    fp = decode_attn.paged_decode_attention(q, kf, vf, pt, lens,
                                            interpret=True)
    drift = float(jnp.abs(out - fp).max())
    assert 0 < drift < 0.05, drift


def test_paged_int8_chunk_prefill_kernel_matches_oracle():
    B, C, H, KV, dh = 2, 8, 4, 2, 16
    ps, n_p = 8, 8
    n_pages = 1 + B * n_p
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, C, H, dh), jnp.float32)
    _, kq, kscale = _quantized_pools(ks[1], n_pages, ps, KV, dh)
    _, vq, vscale = _quantized_pools(ks[2], n_pages, ps, KV, dh)
    rng = np.random.default_rng(8)
    pt = jnp.asarray(
        rng.permutation(np.arange(1, n_pages)).reshape(B, n_p), jnp.int32)
    from repro.kernels.decode import ops as dec_ops
    for off in (0, 8, 21):
        out = dec_ops.paged_chunk_prefill_attention_int8(
            q, kq, vq, kscale, vscale, pt, jnp.int32(off), interpret=True)
        want = dec_ref.paged_chunk_prefill_reference_int8(
            q, kq, vq, kscale, vscale, pt, jnp.int32(off))
        np.testing.assert_allclose(out, want, atol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_matches_contiguous_decode(impl):
    """Scattering a contiguous cache into pages and reading it back through
    the page table reproduces dense decode attention exactly."""
    B, KV, H, dh = 2, 2, 4, 16
    ps, n_p = 8, 4
    Smax = ps * n_p
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Smax, KV, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Smax, KV, dh), jnp.float32)
    lens = jnp.asarray([7, 29], jnp.int32)
    # lay each sequence's pages out in a shuffled shared pool
    rng = np.random.default_rng(1)
    ids = rng.permutation(np.arange(1, 1 + B * n_p)).reshape(B, n_p)
    n_pages = 1 + B * n_p
    kp = jnp.zeros((n_pages, ps, KV, dh), jnp.float32)
    vp = jnp.zeros((n_pages, ps, KV, dh), jnp.float32)
    kp = kp.at[ids].set(kc.reshape(B, n_p, ps, KV, dh))
    vp = vp.at[ids].set(vc.reshape(B, n_p, ps, KV, dh))
    pt = jnp.asarray(ids, jnp.int32)
    fcfg = FamousConfig(impl=impl)
    paged = famous.paged_decode_attention(q, kp, vp, pt, lens, cfg=fcfg)
    dense = famous.decode_attention(q, kc, vc, lens, cfg=fcfg)
    np.testing.assert_allclose(paged, dense, atol=1e-5)


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------


def _engine_outputs(params, cfg, prompts, max_new, **engine_kw):
    engine = ServingEngine(params, cfg, engine_kw.pop("fcfg", FCFG),
                           **engine_kw)
    reqs = [Request(rid=i, tokens=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    assert len(done) == len(prompts)
    return [r.out for r in done]


def test_paged_engine_token_identical_mixed_lengths():
    """6 mixed-length requests through 2 slots: slot reuse after retirement,
    decode-time page growth across page boundaries, length-1 admission."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (5, 9, 17, 3, 33, 1)]
    base = _engine_outputs(params, cfg, prompts, 6, n_slots=2, max_seq=64)
    paged = _engine_outputs(params, cfg, prompts, 6, n_slots=2, max_seq=64,
                            cache_kind="paged", page_size=8)
    assert base == paged


def test_paged_engine_pallas_kernel_path():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n)) for n in (5, 12)]
    xla = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=32,
                          cache_kind="paged", page_size=8)
    pallas = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=32,
                             cache_kind="paged", page_size=8,
                             fcfg=FamousConfig(impl="pallas"))
    assert xla == pallas


def test_paged_engine_int8_kernel_path_matches_xla():
    """Both impls read the SAME quantized pages, so int8 pallas vs int8
    xla is ordinary kernel parity — greedy outputs identical."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n)) for n in (5, 12)]
    xla = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=32,
                          cache_kind="paged", page_size=8, kv_dtype="int8")
    pallas = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=32,
                             cache_kind="paged", page_size=8,
                             kv_dtype="int8",
                             fcfg=FamousConfig(impl="pallas"))
    assert xla == pallas


def test_kv_int8_requires_paged_cache():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    with pytest.raises(AssertionError):
        ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=32,
                      kv_dtype="int8")          # contiguous cache


def test_int8_preemption_keeps_scales_in_lockstep():
    """Preempt/resume on a tiny int8 pool: scale rows ride the same page
    ids as their payload, so a preempted-and-resumed request reproduces
    the un-contended int8 engine's tokens exactly and the drained pool
    holds no stale scale state."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(9)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=7)) for _ in range(2)]
    base = _engine_outputs(params, cfg, prompts, 8, n_slots=2, max_seq=32,
                           cache_kind="paged", page_size=4,
                           kv_dtype="int8")
    paged = _engine_outputs(params, cfg, prompts, 8, n_slots=2, max_seq=32,
                            cache_kind="paged", page_size=4, n_pages=6,
                            kv_dtype="int8")
    assert base == paged


def test_paged_engine_hybrid_arch():
    """Hybrid recurrent/local arch under cache_kind="paged": recurrent state
    and ring buffers keep their per-slot buffers, outputs unchanged."""
    cfg = shrink(get_config("recurrentgemma-2b"))
    params = _params(cfg)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n)) for n in (7, 3, 11)]
    base = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=64)
    paged = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=64,
                            cache_kind="paged", page_size=16)
    assert base == paged


def test_engine_admission_control():
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    # 3-page pool (n_pages=4 incl. null): a 20-token prompt needs 3 pages of
    # 8 -> admissible; a second request then cannot be admitted.
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=32,
                           cache_kind="paged", page_size=8, n_pages=4)
    engine.add_request(Request(rid=0, tokens=list(range(1, 21)), max_new=2))
    with pytest.raises(PagePoolExhausted):
        engine.add_request(Request(rid=1, tokens=list(range(1, 10)), max_new=2))
    # engine state untouched by the failed admission: slot 1 still free,
    # and the first request decodes to completion.
    assert engine.slot_req[1] is None
    done = engine.run([])
    assert len(done) == 1 and len(done[0].out) == 2
    assert engine.alloc.free_pages == 3  # retirement returned every page


def test_engine_preemption_resumes_token_identically():
    """Two sequences whose decode-time growth collides on the last free
    page: the younger is preempted mid-generation, resumed after the elder
    retires, and still produces exactly the contiguous-engine tokens."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=7)) for _ in range(2)]
    base = _engine_outputs(params, cfg, prompts, 8, n_slots=2, max_seq=32)
    # 5 allocatable pages of 4: both prompts admit (2 pages each), the first
    # boundary crossing takes the last page, the second forces a preemption
    paged = _engine_outputs(params, cfg, prompts, 8, n_slots=2, max_seq=32,
                            cache_kind="paged", page_size=4, n_pages=6)
    assert base == paged


def test_engine_impossible_request_fails_cleanly():
    """run() returns impossible requests with req.error set instead of
    discarding completed work."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(6)
    ok = Request(rid=0, tokens=list(rng.integers(0, cfg.vocab_size, size=5)),
                 max_new=3)
    huge = Request(rid=1, tokens=list(rng.integers(0, cfg.vocab_size, size=30)),
                   max_new=3)  # needs 4 pages, pool only has 3
    engine = ServingEngine(params, cfg, FCFG, n_slots=2, max_seq=32,
                           cache_kind="paged", page_size=8, n_pages=4)
    done = sorted(engine.run([ok, huge]), key=lambda r: r.rid)
    assert len(done) == 2
    assert done[0].error is None and len(done[0].out) == 3
    assert done[1].error is not None and "pages" in done[1].error


def test_engine_oversubscribed_pool_drains_queue():
    """A pool half the contiguous footprint still serves every request —
    admission simply waits for pages to free (the scale story: memory
    follows live tokens, not n_slots x max_seq)."""
    cfg = shrink(get_config("qwen2-7b"))
    params = _params(cfg)
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (9, 5, 13, 7)]
    # contiguous-equivalent would need 2 slots x 8 pages; give it 5 (+null)
    outs = _engine_outputs(params, cfg, prompts, 4, n_slots=2, max_seq=64,
                           cache_kind="paged", page_size=8, n_pages=6)
    assert all(len(o) == 4 for o in outs)

"""Gradient parity of the Pallas flash-attention custom VJP (interpret mode
on CPU) against the reference path: jax.grad through
``attention(..., cfg=FamousConfig(impl="pallas"))`` must match the
materialised-S oracle within fp32 tolerance for causal, windowed and GQA
configurations — with the backward running the Pallas dq / dk-dv kernels,
never the XLA flash backward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import famous
from repro.kernels.attention import mha as mha_kernel
from repro.kernels.attention import ops as attn_ops

# B, S, H, KV, dh, causal, window, block_q, block_k
CASES = [
    (2, 128, 4, 4, 32, True, 0, 64, 64),      # causal MHA
    (2, 128, 4, 2, 32, True, 0, 64, 64),      # causal GQA (group 2)
    (1, 256, 4, 1, 16, True, 64, 64, 128),    # windowed causal MQA
    (2, 128, 4, 4, 32, False, 0, 128, 64),    # bidirectional
    (1, 192, 6, 3, 16, True, 32, 96, 64),     # window + GQA, uneven blocks
]


def _inputs(B, S, H, KV, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, KV, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, KV, dh)) * 0.5
    w = jax.random.normal(ks[3], (B, S, H, dh))   # cotangent projection
    return q, k, v, w


@pytest.mark.parametrize("B,S,H,KV,dh,causal,window,bq,bk", CASES)
def test_pallas_grad_matches_reference(B, S, H, KV, dh, causal, window,
                                       bq, bk):
    q, k, v, w = _inputs(B, S, H, KV, dh)
    cfg = famous.FamousConfig(impl="pallas", tile_q=bq, tile_k=bk)

    def loss_pallas(q, k, v):
        out = famous.attention(q, k, v, causal=causal, window=window, cfg=cfg)
        return jnp.sum(out * w)

    def loss_ref(q, k, v):
        out = famous.attention_reference(q, k, v, causal=causal,
                                         window=window)
        return jnp.sum(out * w)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gp, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-5, rtol=1e-4, err_msg=f"d{name}")


def test_custom_vjp_forward_regression():
    """The custom-VJP wrapper's primal output is the same kernel forward —
    taking gradients must not perturb the forward value."""
    q, k, v, w = _inputs(2, 128, 4, 2, 32, seed=1)
    ref = famous.attention_reference(q, k, v, causal=True)

    out_plain = attn_ops.mha(q, k, v, causal=True, block_q=64, block_k=64)
    out_vjp, _ = jax.value_and_grad(
        lambda q_: jnp.sum(attn_ops.mha(q_, k, v, causal=True, block_q=64,
                                        block_k=64) * w))(q)
    np.testing.assert_allclose(np.asarray(out_plain), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)
    # value_and_grad over the same wrapper reduces the same forward
    np.testing.assert_allclose(out_vjp, float(jnp.sum(ref * w)), rtol=1e-5)


def test_backward_uses_pallas_kernels(monkeypatch):
    """No fallback: the VJP must trace through mha_backward (the Pallas dq /
    dk-dv kernels), not the XLA flash backward."""
    calls = []
    real = mha_kernel.mha_backward

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(mha_kernel, "mha_backward", counting)
    # unique shape so the jitted wrapper cannot reuse a cached trace
    q, k, v, w = _inputs(1, 160, 2, 1, 8, seed=2)
    jax.grad(lambda q_: jnp.sum(attn_ops.mha(
        q_, k, v, causal=True, block_q=32, block_k=32) * w))(q)
    assert calls, "backward did not go through the Pallas mha_backward"


def test_forward_lse_matches_reference_logsumexp():
    """The LSE residual the backward consumes equals the row logsumexp of
    the masked scores."""
    B, S, H, dh = 1, 128, 2, 16
    q, k, v, _ = _inputs(B, S, H, H, dh, seed=3)
    qf, kf = attn_ops._to_flat(q), attn_ops._to_flat(k)
    _, lse = mha_kernel.mha_forward(qf, kf, attn_ops._to_flat(v),
                                    causal=True, block_q=64, block_k=64,
                                    interpret=True, return_lse=True)
    scale = 1.0 / np.sqrt(dh)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None], s, -jnp.inf)
    ref = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_qkv_matmul_grad_matches_xla():
    """The tiled QKV projection kernel differentiates through itself."""
    from repro.kernels.qkv import qkv_proj
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(ks[0], (64, 128)) * 0.5
    w = jax.random.normal(ks[1], (128, 64)) * 0.05
    g = jax.random.normal(ks[2], (64, 64))

    def loss_k(x, w):
        return jnp.sum(qkv_proj.matmul_tiled(x, w, block_t=32, block_f=32,
                                             block_d=64, interpret=True) * g)

    def loss_x(x, w):
        return jnp.sum((x @ w) * g)

    gk = jax.grad(loss_k, argnums=(0, 1))(x, w)
    gx = jax.grad(loss_x, argnums=(0, 1))(x, w)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)

"""Roofline infrastructure: the while-aware HLO cost model must reproduce
analytic FLOP counts (including scan trip multiplication) and parse
collectives correctly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis, hlo_cost


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    M, K, N = 256, 512, 128
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((M, K), jnp.float32),
                  jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = hlo_cost.analyse_hlo(c.as_text())
    assert cost.flops == pytest.approx(2 * M * K * N, rel=1e-6)


def test_scan_trip_count_multiplies_flops():
    M = 128
    n_steps = 12

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n_steps)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                  jax.ShapeDtypeStruct((M, M), jnp.float32))
    cost = hlo_cost.analyse_hlo(c.as_text())
    expect = 2 * M * M * M * n_steps
    assert cost.flops == pytest.approx(expect, rel=0.01), \
        (cost.flops, expect, cost.while_trips)
    # XLA's builtin analysis undercounts by ~n_steps — the reason this
    # module exists:
    xla_flops = hlo_cost.cost_analysis_dict(c).get("flops", 0)
    assert xla_flops < cost.flops / 4


def test_bytes_reasonable_for_elementwise():
    N = 1 << 20

    def f(x):
        return x * 2.0 + 1.0

    c = _compiled(f, jax.ShapeDtypeStruct((N,), jnp.float32))
    cost = hlo_cost.analyse_hlo(c.as_text())
    # read + write of one f32 buffer ~ 8 MB; allow 3x for copies
    assert 4e6 <= cost.bytes_accessed <= 3e7, cost.bytes_accessed


SYNTH_HLO = """
HloModule synth

ENTRY %main (p0: f32[1024,256]) -> f32[1024,256] {
  %p0 = f32[1024,256]{1,0} parameter(0)
  %ar = f32[1024,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096,256]{1,0} all-gather(%ar), replica_groups=[64,4]<=[256], dimensions={0}
  ROOT %cp = f32[1024,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""


def test_collective_parse_synthetic():
    coll = analysis.collective_bytes(SYNTH_HLO)
    b = 1024 * 256 * 4
    assert coll["all-reduce"] == pytest.approx(2 * b * 3 / 4)
    assert coll["all-gather"] == pytest.approx(4 * b * 3 / 4)
    assert coll["collective-permute"] == pytest.approx(b)
    assert coll["counts"]["all-reduce"] == 1


def test_model_flops_definitions():
    from repro.configs.base import SHAPES, get_config
    cfg = get_config("deepseek-7b")
    n = cfg.param_count()
    assert analysis.model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6.0 * n * 256 * 4096)
    assert analysis.model_flops(cfg, SHAPES["decode_32k"]) == pytest.approx(
        2.0 * n * 128)
    moe = get_config("kimi-k2-1t-a32b")
    assert analysis.model_flops(moe, SHAPES["train_4k"]) < \
        6.0 * moe.param_count() * 256 * 4096 / 5  # active << total


def test_roofline_dominant_and_fraction():
    r = analysis.Roofline(
        arch="a", shape="s", mesh="m", flops=197e12, bytes_accessed=819e9 / 2,
        coll_bytes=0.0, t_compute=1.0, t_memory=0.5, t_collective=0.0,
        model_flops_total=197e12 * 256, chips=256, coll_detail={})
    assert r.dominant == "compute"
    assert r.roofline_fraction == pytest.approx(1.0)
    assert r.useful_flop_ratio == pytest.approx(1.0)

"""Data pipeline: determinism, host-sharded equality, prefetch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SMOKE_SHAPES, get_config, shrink
from repro.data import pipeline
from repro.launch.mesh import make_mesh


CFG = shrink(get_config("qwen2-7b"))
VCFG = shrink(get_config("llava-next-34b"))
SHAPE = SMOKE_SHAPES["smoke_train"]


def test_determinism():
    b1 = pipeline.host_batch(CFG, SHAPE, seed=1, step=7)
    b2 = pipeline.host_batch(CFG, SHAPE, seed=1, step=7)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = pipeline.host_batch(CFG, SHAPE, seed=1, step=8)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    b4 = pipeline.host_batch(CFG, SHAPE, seed=2, step=7)
    assert not np.array_equal(b1["inputs"], b4["inputs"])


def test_targets_are_shifted_inputs():
    b = pipeline.host_batch(CFG, SHAPE, seed=0, step=0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_row_slices_compose():
    """Building rows [lo,hi) independently equals slicing the full batch —
    the property that lets 1000 hosts each build only their shard."""
    full = pipeline.host_batch(CFG, SHAPE, seed=3, step=5)
    part = pipeline.host_batch(CFG, SHAPE, seed=3, step=5, lo=1, hi=2)
    np.testing.assert_array_equal(full["inputs"][1:2], part["inputs"])


def test_frontend_batches():
    b = pipeline.host_batch(VCFG, SHAPE, seed=0, step=0)
    assert b["inputs"].shape == (SHAPE.global_batch, SHAPE.seq_len,
                                 VCFG.d_model)
    assert b["inputs"].dtype == np.float32
    assert b["targets"].shape == (SHAPE.global_batch, SHAPE.seq_len)


def test_global_batch_sharded():
    mesh = make_mesh((1, 1), ("data", "model"))
    from repro.parallel import sharding as shd
    sh = shd.batch_sharding(mesh, 2, None,
                            (SHAPE.global_batch, SHAPE.seq_len))
    b = pipeline.make_global_batch(CFG, SHAPE, seed=0, step=0, sharding=sh)
    host = pipeline.host_batch(CFG, SHAPE, seed=0, step=0)
    np.testing.assert_array_equal(np.asarray(b["inputs"]), host["inputs"])


def test_prefetch_iterator():
    mesh = make_mesh((1, 1), ("data", "model"))
    from repro.parallel import sharding as shd
    sh = shd.batch_sharding(mesh, 2, None,
                            (SHAPE.global_batch, SHAPE.seq_len))
    it = pipeline.PrefetchIterator(CFG, SHAPE, pipeline.DataConfig(), sh)
    try:
        s0, b0 = next(it)
        s1, b1 = next(it)
        assert (s0, s1) == (0, 1)
        ref = pipeline.host_batch(CFG, SHAPE, seed=0, step=1)
        np.testing.assert_array_equal(np.asarray(b1["inputs"]), ref["inputs"])
    finally:
        it.close()

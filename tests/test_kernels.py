"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
pure-jnp oracles (interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention import ref as attn_ref
from repro.kernels.decode import ops as dec_ops
from repro.kernels.decode import ref as dec_ref
from repro.kernels.qkv import ops as qkv_ops
from repro.kernels.qkv import qkv_proj
from repro.kernels.qkv import ref as qkv_ref
from repro.kernels.scan import ops as scan_ops
from repro.kernels.scan import ref as scan_ref


def _rand(key, shape, dtype, scale=0.5):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# fused MHA kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,dh,bq,bk", [
    (2, 256, 4, 2, 64, 128, 128),
    (1, 512, 8, 8, 32, 256, 128),
    (2, 128, 4, 1, 64, 64, 64),
    (1, 256, 6, 2, 16, 128, 256),   # block_k > Skv clamps
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mha_kernel_matches_ref(B, S, H, KV, dh, bq, bk, causal, dtype):
    q = _rand(0, (B, S, H, dh), dtype)
    k = _rand(1, (B, S, KV, dh), dtype)
    v = _rand(2, (B, S, KV, dh), dtype)
    out = attn_ops.mha(q, k, v, causal=causal, block_q=bq, block_k=bk)
    r = attn_ref.mha_reference(attn_ops._to_flat(q), attn_ops._to_flat(k),
                               attn_ops._to_flat(v), causal=causal)
    r = attn_ops._from_flat(r, B, H)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_mha_kernel_window():
    q = _rand(0, (2, 256, 4, 32), jnp.float32)
    k = _rand(1, (2, 256, 2, 32), jnp.float32)
    v = _rand(2, (2, 256, 2, 32), jnp.float32)
    out = attn_ops.mha(q, k, v, causal=True, window=64, block_q=64, block_k=64)
    r = attn_ops._from_flat(
        attn_ref.mha_reference(attn_ops._to_flat(q), attn_ops._to_flat(k),
                               attn_ops._to_flat(v), causal=True, window=64),
        2, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-6)


# ---------------------------------------------------------------------------
# tiled QKV projection kernel (Algorithm 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,D,F,bt,bf,bd", [
    (128, 256, 192, 64, 64, 64),
    (256, 512, 128, 128, 128, 256),
    (64, 128, 128, 64, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_tiled(T, D, F, bt, bf, bd, dtype):
    x = _rand(3, (T, D), dtype)
    w = _rand(4, (D, F), dtype, scale=0.05)
    out = qkv_proj.matmul_tiled(x, w, block_t=bt, block_f=bf, block_d=bd,
                                interpret=True)
    r = qkv_ref.matmul_reference(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_matmul_tiled_int8():
    x = _rand(5, (128, 256), jnp.float32)
    w = _rand(6, (256, 128), jnp.float32, scale=0.05)
    xq, sx = quant.quantize(x, axis=1)
    wq, sw = quant.quantize(w, axis=0)
    out = qkv_proj.matmul_tiled_int8(xq, wq, sx, sw, block_d=128,
                                     interpret=True)
    r = qkv_ref.matmul_int8_reference(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-4)
    # and the int8 result approximates the f32 matmul
    full = qkv_ref.matmul_reference(x, w, out_dtype=jnp.float32)
    err = np.abs(np.asarray(out) - np.asarray(full)).max()
    assert err < 0.05, err


@pytest.mark.parametrize("quant_mode", ["none", "int8"])
def test_qkv_projection_wrapper(quant_mode):
    B, S, D, H, KV, dh = 2, 32, 128, 4, 2, 16
    x = _rand(7, (B, S, D), jnp.float32)
    wq = _rand(8, (D, H, dh), jnp.float32, 0.05)
    wk = _rand(9, (D, KV, dh), jnp.float32, 0.05)
    wv = _rand(10, (D, KV, dh), jnp.float32, 0.05)
    bq = _rand(11, (H, dh), jnp.float32, 0.01)
    bk = _rand(12, (KV, dh), jnp.float32, 0.01)
    bv = _rand(13, (KV, dh), jnp.float32, 0.01)
    q, k, v = qkv_ops.qkv_projection(x, wq, wk, wv, bq, bk, bv,
                                     tile_d=64, quant=quant_mode)
    qr, kr, vr = qkv_ref.qkv_reference(x, wq, wk, wv, bq, bk, bv)
    tol = 1e-5 if quant_mode == "none" else 0.05
    for a, b in [(q, qr), (k, kr), (v, vr)]:
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol)


# ---------------------------------------------------------------------------
# decode attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,dh,Skv,bk,window", [
    (2, 4, 2, 32, 256, 64, 0),
    (3, 8, 1, 16, 128, 128, 0),
    (2, 4, 4, 32, 256, 64, 16),
])
def test_decode_kernel(B, H, KV, dh, Skv, bk, window):
    q = _rand(14, (B, 1, H, dh), jnp.float32)
    kc = _rand(15, (B, Skv, KV, dh), jnp.float32)
    vc = _rand(16, (B, Skv, KV, dh), jnp.float32)
    clen = jnp.asarray(np.random.default_rng(0).integers(1, Skv, B), jnp.int32)
    out = dec_ops.decode_attention(q, kc, vc, clen, window=window, block_k=bk)
    group = H // KV
    qf = q[:, 0].reshape(B, KV, group, dh).reshape(B * KV, group, dh)
    kf = kc.transpose(0, 2, 1, 3).reshape(B * KV, Skv, dh)
    vf = vc.transpose(0, 2, 1, 3).reshape(B * KV, Skv, dh)
    r = dec_ref.decode_reference(qf, kf, vf, jnp.repeat(clen, KV),
                                 window=window)
    r = r.reshape(B, KV, group, dh).reshape(B, 1, H, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-6)


# ---------------------------------------------------------------------------
# linear-recurrence kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,R,br,bs", [
    (2, 128, 96, 32, 32),
    (1, 64, 256, 128, 64),
    (3, 96, 32, 32, 32),
])
def test_rglru_kernel(B, S, R, br, bs):
    a = jax.nn.sigmoid(_rand(17, (B, S, R), jnp.float32, 1.0))
    b = _rand(18, (B, S, R), jnp.float32, 0.1)
    out = scan_ops.rglru(a, b, block_r=br, block_s=bs)
    r = scan_ref.rglru_reference(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (2, 3, 128, 16, 32),
    (1, 2, 64, 32, 64),
    (2, 1, 96, 16, 32),
])
def test_wkv6_kernel(B, H, S, dh, chunk):
    r = _rand(19, (B, H, S, dh), jnp.float32)
    k = _rand(20, (B, H, S, dh), jnp.float32)
    v = _rand(21, (B, H, S, dh), jnp.float32)
    logw = -jnp.exp(jnp.clip(_rand(22, (B, H, S, dh), jnp.float32, 1.0),
                             -20, 0))
    u = _rand(23, (H, dh), jnp.float32)
    out = scan_ops.wkv6(r, k, v, logw, u, chunk=chunk)
    flat = lambda x: x.reshape(B * H, S, dh)
    uu = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh)
    ref = scan_ref.wkv6_reference(flat(r), flat(k), flat(v), flat(logw), uu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref).reshape(
        B * H, S, dh).reshape(B, H, S, dh), atol=1e-4, rtol=1e-3)

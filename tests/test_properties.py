"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import famous, quant
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.configs.base import get_config, shrink
from repro.serve.engine import next_pow2

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# attention invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 3), st.sampled_from([32, 64, 128]),
       st.sampled_from([1, 2, 4]), st.sampled_from([8, 16]),
       st.booleans(), st.integers(0, 3))
def test_flash_equals_reference(B, S, H, dh, causal, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh)) * 0.7
    k = jax.random.normal(ks[1], (B, S, H, dh)) * 0.7
    v = jax.random.normal(ks[2], (B, S, H, dh)) * 0.7
    out = famous.attention_xla(q, k, v, causal=causal, block_k=32)
    ref = famous.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


@settings(**SETTINGS)
@given(st.integers(0, 5))
def test_attention_rows_are_convex_combinations(seed):
    """Each output row lies in the convex hull of V rows => bounded by
    per-column min/max of V (softmax weights sum to 1)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    out = famous.attention_reference(q, k, v, causal=False)
    lo = v.min(axis=1, keepdims=True) - 1e-5
    hi = v.max(axis=1, keepdims=True) + 1e-5
    assert bool(((out >= lo) & (out <= hi)).all())


@settings(**SETTINGS)
@given(st.integers(0, 5), st.sampled_from([16, 64]))
def test_causal_prefix_invariance(seed, S):
    """Causality: logits at position t do not depend on tokens > t."""
    cfg = shrink(get_config("qwen2-7b"))
    from repro.models import module, transformer
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, S), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 7) % cfg.vocab_size)
    l1 = transformer.forward(params, toks, cfg, remat=False)
    l2 = transformer.forward(params, toks2, cfg, remat=False)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), atol=1e-5)


# ---------------------------------------------------------------------------
# quantization invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 10), st.sampled_from([(4, 16), (8, 8), (1, 64)]))
def test_quantize_bounds_and_scale_recovery(seed, shape):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * \
        (10.0 ** (seed % 4 - 2))
    q, s = quant.quantize(x, axis=-1)
    assert int(jnp.abs(q.astype(jnp.int32)).max()) <= 127
    err = jnp.abs(quant.dequantize(q, s) - x)
    assert bool((err <= s * 0.5 + 1e-9).all())


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 5), st.sampled_from([(8, 2), (4, 1), (16, 4)]),
       st.floats(1.0, 2.0))
def test_router_dispatch_invariants(seed, ek, cf):
    E, K = ek
    G, S = 2, 32
    logits = jax.random.normal(jax.random.PRNGKey(seed), (G, S, E))
    dispatch, combine, aux = moe_lib.router_dispatch(logits, K, cf)
    d = np.asarray(dispatch, np.float32)
    c = np.asarray(combine, np.float32)
    # each (expert, slot) pair holds at most one token
    assert d.sum(axis=1).max() <= 1.0 + 1e-6
    # each token occupies at most K slots, combine weights sum to <= 1
    assert d.sum(axis=(2, 3)).max() <= K + 1e-6
    assert c.sum(axis=(2, 3)).max() <= 1.0 + 1e-5
    assert c.min() >= 0.0
    # aux loss is >= 1 (perfect balance) up to estimator noise
    assert float(aux) > 0.5


@settings(**SETTINGS)
@given(st.integers(0, 3))
def test_moe_capacity_drop_monotone(seed):
    """Higher capacity factor can only reduce dropped tokens."""
    E, K, G, S = 8, 2, 2, 64
    logits = jax.random.normal(jax.random.PRNGKey(seed), (G, S, E))
    kept = []
    for cf in (0.5, 1.0, 2.0):
        d, _, _ = moe_lib.router_dispatch(logits, K, cf)
        kept.append(float(np.asarray(d).sum()))
    assert kept[0] <= kept[1] <= kept[2]


# ---------------------------------------------------------------------------
# recurrence invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 5))
def test_rglru_associative_scan_matches_sequential(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    B, S, R = 2, 48, 16
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, R)))
    b = jax.random.normal(ks[1], (B, S, R)) * 0.3

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h_assoc = jax.lax.associative_scan(combine, (a, b), axis=1)
    from repro.kernels.scan.ref import rglru_reference
    h_seq = rglru_reference(a, b)
    np.testing.assert_allclose(np.asarray(h_assoc), np.asarray(h_seq),
                               atol=1e-5, rtol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 5))
def test_rglru_decay_bounded(seed):
    """|h_t| stays bounded when |b| bounded and a in (0,1): BIBO stability."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 256, 8)))
    b = jnp.clip(jax.random.normal(ks[1], (1, 256, 8)), -1, 1) * (1 - a)
    from repro.kernels.scan.ref import rglru_reference
    h = rglru_reference(a, b)
    assert float(jnp.abs(h).max()) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 10_000))
def test_next_pow2(n):
    b = next_pow2(n)
    assert b >= n and b & (b - 1) == 0
    assert b < 2 * max(n, 2)

"""Direct unit tests for core/quant.py (symmetric int8 machinery).

Previously only covered incidentally through the ``quant="int8"`` FAMOUS
config; these pin the contracts the quantized KV cache now depends on:
roundtrip error bound, scale shape/broadcast, and the int8_einsum
accumulation/out_dtype contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant


def test_quantize_scale_shape_keepdims():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 8))
    for axis, want in [(-1, (3, 5, 1)), (0, (1, 5, 8)), (1, (3, 1, 8))]:
        q, s = quant.quantize(x, axis=axis)
        assert q.dtype == jnp.int8
        assert s.shape == want, (axis, s.shape)
        # scale broadcasts back against q without reshaping
        assert quant.dequantize(q, s).shape == x.shape


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64)) * 3.0
    q, s = quant.quantize(x, axis=-1)
    err = jnp.abs(quant.dequantize(q, s) - x)
    # rounding to the nearest of 255 levels: |err| <= scale/2 per row
    assert jnp.all(err <= s / 2 + 1e-7)
    # and q saturates the grid: every row's amax maps to +/-127
    assert int(jnp.max(jnp.abs(q))) == 127


def test_quantize_near_zero_rows_stable():
    x = jnp.zeros((4, 8), jnp.float32)
    q, s = quant.quantize(x, axis=-1)
    assert not np.any(np.isnan(np.asarray(s)))
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_allclose(np.asarray(quant.dequantize(q, s)), 0.0)


def test_int8_einsum_matches_fp_einsum():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
    got = quant.int8_einsum("bd,df->bf", x, w)
    want = jnp.einsum("bd,df->bf", x, w)
    # two int8 grids: relative error a few percent of the output magnitude
    tol = 0.05 * float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) < tol


@pytest.mark.parametrize("out_dtype", [None, jnp.float32, jnp.bfloat16])
def test_int8_einsum_out_dtype_contract(out_dtype):
    """bf16 inputs: accumulate wide, cast once at the end (docstring)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 32)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8)).astype(jnp.bfloat16)
    out = quant.int8_einsum("bd,df->bf", x, w, out_dtype=out_dtype)
    assert out.dtype == (x.dtype if out_dtype is None else out_dtype)
    # values agree with the fp32 out_dtype result up to the final rounding
    wide = quant.int8_einsum("bd,df->bf", x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(wide), rtol=1e-2, atol=1e-2)

"""Quickstart: the FAMOUS attention core in 60 lines.

Runs the paper-faithful reference, the TPU-adapted XLA path and the Pallas
kernel (interpret mode on CPU) on the paper's topology, checks they agree,
and shows the §VII analytical model + tile autotuner.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import analytical, famous

# the paper's Table I test #1 topology: SL=64, d_model=768, h=8
B, SL, D, H = 1, 64, 768, 8
dh = D // H

ks = jax.random.split(jax.random.PRNGKey(0), 4)
x = jax.random.normal(ks[0], (B, SL, D), jnp.float32)
wq, wk, wv = (jax.random.normal(k, (D, H, dh), jnp.float32) * 0.05
              for k in ks[1:])

outs = {}
for impl in ("reference", "xla", "pallas"):
    cfg = famous.FamousConfig(impl=impl, tile_d=64, tile_q=64, tile_k=64)
    q, k, v = famous.qkv_projection(x, wq, wk, wv, cfg=cfg)
    outs[impl] = famous.attention(q, k, v, causal=False, cfg=cfg)
    print(f"{impl:10s} -> attention out {outs[impl].shape}, "
          f"mean={float(jnp.mean(outs[impl])):+.6f}")

err = float(jnp.abs(outs["pallas"] - outs["reference"]).max())
print(f"max |pallas - reference| = {err:.2e}")
assert err < 1e-4

print("\nAnalytical model (paper §VII, adapted to TPU v5e):")
lat = analytical.mha_latency(batch=B, seq=SL, heads=H, kv_heads=H,
                             head_dim=dh, d_model=D, tile_q=64, tile_k=64,
                             tile_d=64)
print(lat.table())
print(f"\npredicted GOPS (dense, bf16): {lat.gops():.0f}")

print("\nTile autotune (replaces the paper's 36 h trial synthesis):")
tuned = analytical.autotune_tiles(batch=8, seq=2048, heads=H, kv_heads=H,
                                  head_dim=dh, d_model=D)
print(f"  best tiles: {tuned['tiles']}  "
      f"predicted total: {tuned['latency'].total*1e6:.1f} us")

"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps through the full production path (sharded state, deterministic
pipeline, fault-tolerant trainer, async checkpoints).

Default runs a ~20M model for 200 steps so it finishes quickly on this
1-core CPU container; pass ``--m100`` for the full ~100M × 300-step run
(same code path, ~40x more FLOPs).

    PYTHONPATH=src python examples/train_lm.py [--m100] [--steps N]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.train import build
from repro.train import trainer as trainer_lib


def lm_config(m100: bool) -> ModelConfig:
    if m100:  # ~103M params
        return ModelConfig(name="lm100m", family="dense", num_layers=12,
                           d_model=768, num_heads=12, num_kv_heads=12,
                           d_ff=2048, vocab_size=32768, tie_embeddings=True)
    return ModelConfig(name="lm20m", family="dense", num_layers=6,
                       d_model=384, num_heads=6, num_kv_heads=6,
                       d_ff=1024, vocab_size=16384, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m100", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_config(args.m100)
    shape = ShapeConfig("train_ex", args.seq, args.batch, "train")

    import repro.configs.base as base
    base._REGISTRY.setdefault(cfg.name, cfg)
    from repro.launch.mesh import make_smoke_mesh
    from repro.train.step import TrainConfig
    _, mesh, state, jitted, batch_fn, state_sh = build(
        cfg.name, shape, smoke=False, mesh=make_smoke_mesh(), seed=0,
        tcfg=TrainConfig(compute_dtype=jnp.float32))
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params), "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    tr = trainer_lib.Trainer(
        jitted, state, batch_fn,
        trainer_lib.TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                  ckpt_dir=args.ckpt_dir))
    with mesh:
        tr.run()
    log = tr.metrics_log
    for m in log[:: max(len(log) // 10, 1)]:
        print(f"  step {m['step']:>4d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  {m['dt']*1e3:.0f} ms")
    print(f"final loss: {log[-1]['loss']:.4f} (start {log[0]['loss']:.4f})")
    assert log[-1]["loss"] < log[0]["loss"]


if __name__ == "__main__":
    main()

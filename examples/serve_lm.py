"""Serving driver: batched requests through the continuous-batching engine
(slot scheduling, bucketed prefill, batched decode) on a reduced qwen2-style
model.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine


def main():
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=4, max_seq=256)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=list(rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(4, 64)))),
                    max_new=16)
            for i in range(12)]
    t0 = time.monotonic()
    done = engine.run(reqs)
    dt = time.monotonic() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{len(done)} requests, {tok} new tokens, {dt:.2f}s "
          f"({tok/dt:.1f} tok/s on 1 CPU core)")
    print(f"prefill executables compiled: {engine.prefill_compilations} "
          f"(pow-2 buckets over prompt lengths 4..64)")
    for r in done[:4]:
        print(f"  req {r.rid:2d} | prompt len {len(r.tokens):2d} -> {r.out}")


if __name__ == "__main__":
    main()

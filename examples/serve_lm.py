"""Serving driver: batched requests through the Scheduler/Runtime engine
(token-budgeted chunked prefill interleaved with batched decode) on a
reduced qwen2-style model — once monolithic, once chunked, and once
chunked+paged — checking the generated tokens are identical every way
(docs/serving.md).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine


def serve(params, cfg, reqs, label, **kw):
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=4, max_seq=256, **kw)
    t0 = time.monotonic()
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    dt = time.monotonic() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{label:22s}: {len(done)} requests, {tok} new tokens, "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s on 1 CPU core), "
          f"prefill executables: {engine.prefill_compilations}")
    return done


def main():
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(4, 180))))
               for _ in range(12)]

    def reqs():
        return [Request(rid=i, tokens=list(p), max_new=16)
                for i, p in enumerate(prompts)]

    mono = serve(params, cfg, reqs(), "monolithic",
                 prefill_mode="monolithic")
    chunked = serve(params, cfg, reqs(), "chunked")
    paged = serve(params, cfg, reqs(), "chunked + paged",
                  cache_kind="paged", page_size=16)
    assert [r.out for r in mono] == [r.out for r in chunked], \
        "chunked prefill must be token-identical"
    assert [r.out for r in mono] == [r.out for r in paged], \
        "paged cache must be token-identical"
    print("monolithic == chunked == chunked+paged, token for token")
    for r in mono[:4]:
        print(f"  req {r.rid:2d} | prompt len {len(r.tokens):3d} -> {r.out}")


if __name__ == "__main__":
    main()

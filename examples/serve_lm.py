"""Serving driver: batched requests through the continuous-batching engine
(slot scheduling, bucketed prefill, batched decode) on a reduced qwen2-style
model — once with the contiguous per-slot KV cache and once with the paged
cache, checking the generated tokens are identical (docs/serving.md).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.serve.engine import Request, ServingEngine


def serve(params, cfg, reqs, **kw):
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=4, max_seq=256, **kw)
    t0 = time.monotonic()
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    dt = time.monotonic() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{engine.cache_kind:10s}: {len(done)} requests, {tok} new tokens, "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s on 1 CPU core), "
          f"prefill executables: {engine.prefill_compilations}")
    return done


def main():
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(4, 64))))
               for _ in range(12)]

    def reqs():
        return [Request(rid=i, tokens=list(p), max_new=16)
                for i, p in enumerate(prompts)]

    base = serve(params, cfg, reqs())
    paged = serve(params, cfg, reqs(), cache_kind="paged", page_size=16)
    assert [r.out for r in base] == [r.out for r in paged], \
        "paged cache must be token-identical"
    print("paged == contiguous, token for token")
    for r in base[:4]:
        print(f"  req {r.rid:2d} | prompt len {len(r.tokens):2d} -> {r.out}")


if __name__ == "__main__":
    main()

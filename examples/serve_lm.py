"""Serving driver: batched requests through the Scheduler/Runtime engine
(token-budgeted chunked prefill interleaved with batched decode) on a
reduced qwen2-style model — once monolithic, once chunked, once
chunked+paged, and once with the prefix cache (cold then warm) — checking
the generated tokens are identical every way (docs/serving.md).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.obs.trace import now
from repro.serve.engine import Request, ServingEngine


def serve(params, cfg, reqs, label, engine=None, **kw):
    engine = engine or ServingEngine(params, cfg, FamousConfig(impl="xla"),
                                     n_slots=4, max_seq=256, **kw)
    t0 = now()
    done = sorted(engine.run(reqs), key=lambda r: r.rid)
    dt = now() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{label:22s}: {len(done)} requests, {tok} new tokens, "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s on 1 CPU core), "
          f"prefill executables: {engine.prefill_compilations}")
    return done, engine


def main():
    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(4, 180))))
               for _ in range(12)]

    def reqs():
        return [Request(rid=i, tokens=list(p), max_new=16)
                for i, p in enumerate(prompts)]

    mono, _ = serve(params, cfg, reqs(), "monolithic",
                    prefill_mode="monolithic")
    chunked, _ = serve(params, cfg, reqs(), "chunked")
    paged, _ = serve(params, cfg, reqs(), "chunked + paged",
                     cache_kind="paged", page_size=16)
    # prefix cache: the first pass publishes every prompt's full blocks on
    # retirement; the second pass (same prompts, same engine) aliases them
    # and skips the cached prefill outright — still token-identical.
    cold, eng = serve(params, cfg, reqs(), "prefix cache (cold)",
                      cache_kind="paged", page_size=16, prefix_cache=True)
    warm, _ = serve(params, cfg, reqs(), "prefix cache (warm)", engine=eng)
    assert [r.out for r in mono] == [r.out for r in chunked], \
        "chunked prefill must be token-identical"
    assert [r.out for r in mono] == [r.out for r in paged], \
        "paged cache must be token-identical"
    assert [r.out for r in mono] == [r.out for r in cold] \
        == [r.out for r in warm], "prefix cache must be token-identical"
    assert eng.prefix_hit_pages > 0, "warm pass must alias cached pages"
    print("monolithic == chunked == chunked+paged == prefix-cached "
          "(cold & warm), token for token")
    print(f"  warm pass reused {eng.prefix_hit_pages} pages / "
          f"{eng.prefix_hit_tokens} prompt tokens from the prefix cache")
    for r in mono[:4]:
        print(f"  req {r.rid:2d} | prompt len {len(r.tokens):3d} -> {r.out}")

    # int8 quantized KV: same pool geometry, ~3.2x the live tokens per byte.
    # Quantization is lossy, so on THIS random-init model (near-tie logit
    # margins) greedy tokens may flip at a few positions — the drift-bounded
    # parity gate lives in benchmarks/serving_bench.py, which checks
    # token-identical greedy on a trained model instead.  Here we assert the
    # memory win and that errors stay at zero, and report the agreement.
    q8, qeng = serve(params, cfg, reqs(), "chunked + paged int8",
                     cache_kind="paged", page_size=16, kv_dtype="int8")
    assert all(r.error is None for r in q8)
    bytes_of = lambda e: sum(b.size * b.dtype.itemsize for b in
                             jax.tree_util.tree_leaves(e.caches))
    _, fpeng = serve(params, cfg, reqs(), "chunked + paged fp",
                     cache_kind="paged", page_size=16)
    ratio = bytes_of(qeng) / bytes_of(fpeng)
    assert ratio <= 0.55, f"int8 cache bytes ratio {ratio:.3f} not halved"
    agree = np.mean([a == b for rf, rq in zip(mono, q8)
                     for a, b in zip(rf.out, rq.out)])
    print(f"int8 KV cache: {ratio:.2f}x the fp cache bytes, "
          f"{agree:.0%} token agreement with fp greedy on random-init "
          f"weights (trained-model parity gated in serving_bench)")


if __name__ == "__main__":
    main()

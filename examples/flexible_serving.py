"""Runtime programmability demo — the paper's §IV-C on TPU.

FAMOUS synthesises once and then reconfigures (heads, d_model, SL) from
software with zero re-synthesis (Table I tests #1–#8: one bitstream, eight
topologies).  Here: ONE compiled XLA executable serves eight attention
topologies; a shape-bucketed cache shows the complementary trade-off.

    PYTHONPATH=src python examples/flexible_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core import famous
from repro.core.flexible import BucketCache, FlexibleAttention, next_pow2

MAXIMA = dict(max_heads=8, max_seq=128, max_head_dim=96)
print(f"'synthesis-time' maxima: {MAXIMA}")
fa = FlexibleAttention(**MAXIMA, causal=True)

# Table I runtime sweep: vary h (tests 1-3), d_head (4-5), SL (6-8)
TOPOLOGIES = [(8, 64, 96), (4, 64, 96), (2, 64, 96),
              (8, 64, 64), (8, 64, 32),
              (8, 128, 96), (8, 32, 96), (8, 16, 96)]

for H, SL, dh in TOPOLOGIES:
    ks = jax.random.split(jax.random.PRNGKey(H * SL + dh), 3)
    q, k, v = (jax.random.normal(kk, (2, SL, H, dh)) * 0.5 for kk in ks)
    t0 = time.perf_counter()
    out = fa(q, k, v)
    dt = (time.perf_counter() - t0) * 1e3
    ref = famous.attention_reference(q, k, v, causal=True)
    err = float(jnp.abs(out - ref).max())
    print(f"  topology (h={H}, SL={SL:3d}, dh={dh:2d}): {dt:7.1f} ms  "
          f"err vs dedicated kernel: {err:.1e}")

print(f"executables compiled: {fa._fn._cache_size()} "
      "(one — every topology reused it)")

print("\nbucketed alternative (compile per pow-2 bucket, no padding waste):")
cache = BucketCache(lambda x, bucket: jnp.tanh(x))
for n in (10, 17, 33, 60, 100, 120):
    fn, b = cache.get(n)
    print(f"  seq {n:3d} -> bucket {b:3d}")
print(f"bucket executables: {len(cache)}  (hits={cache.hits})")

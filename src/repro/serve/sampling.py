"""Batched per-request token sampling for the serving engine.

One jitted executable samples every slot of the decode batch at once;
everything request-specific — temperature, top-k, seed, position — arrives
as plain per-slot operands, so the executable never recompiles when the
request mix changes (the same "reprogram, never re-synthesise" contract as
the decode step itself).

Reproducibility: the PRNG key for a slot is
``fold_in(PRNGKey(seed), token_index)`` — a pure function of the
*request's* seed and how many tokens it has generated, independent of
which slot it landed in, what else is in the batch, or preemption/resume
history.  A seeded request therefore samples the same tokens in any
engine configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, temperature, top_k, seed, index):
    """Sample one token per slot.

    logits: (B, vocab) f32; temperature: (B,) f32 — ``<= 0`` means greedy
    (argmax, the default); top_k: (B,) int32 — ``0`` disables the top-k
    filter; seed: (B,) int32 per-request PRNG seed; index: (B,) int32
    per-request token index (``len(req.out)``).  Returns (B,) int32.
    """
    def one(lg, t, k, s, idx):
        greedy = jnp.argmax(lg).astype(jnp.int32)
        v = lg.shape[-1]
        # top-k: keep logits >= the k-th largest (k == 0 -> keep all)
        kth = jnp.sort(lg)[::-1][jnp.clip(k, 1, v) - 1]
        masked = jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)
        key = jax.random.fold_in(jax.random.PRNGKey(s), idx)
        g = jax.random.gumbel(key, lg.shape, lg.dtype)
        sampled = jnp.argmax(masked / jnp.maximum(t, 1e-6) + g)
        return jnp.where(t > 0, sampled.astype(jnp.int32), greedy)

    return jax.vmap(one)(logits, temperature, top_k, seed, index)

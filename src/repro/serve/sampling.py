"""Batched per-request token sampling for the serving engine.

One jitted executable samples every slot of the decode batch at once;
everything request-specific — temperature, top-k, seed, position — arrives
as plain per-slot operands, so the executable never recompiles when the
request mix changes (the same "reprogram, never re-synthesise" contract as
the decode step itself).  The only static input is ``k_cap``, a pow-2
upper bound on the batch's largest top-k: ``jax.lax.top_k`` needs a static
k, and thresholding against the top ``k_cap`` values replaces the old
full-vocab sort (O(V log V) per slot) with O(V · log k_cap) work — at most
O(log V) executables ever exist, one per pow-2 bucket actually requested.

Reproducibility: the PRNG key for a slot is
``fold_in(PRNGKey(seed), token_index)`` — a pure function of the
*request's* seed and how many tokens it has generated, independent of
which slot it landed in, what else is in the batch, or preemption/resume
history.  A seeded request therefore samples the same tokens in any
engine configuration.  Seeds arrive as uint32 (the engine folds wider
request ids / seeds down with ``fold_seed``, so rids >= 2^31 neither
overflow nor collide by truncation alone).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fold_seed(seed: int) -> int:
    """Fold an arbitrary non-negative python int into uint32 range
    (xor-fold of the high bits — the identity for seeds < 2^32, so
    existing seeded requests keep their exact token streams)."""
    s = int(seed)
    while s >> 32:
        s = (s >> 32) ^ (s & 0xFFFFFFFF)
    return s


def _sample_one(lg, t, k, s, idx, cap):
    """One token from one logit row — a pure function of (seed, token
    index, logits), which is what makes speculative verification exact:
    the verify path samples position ``j`` with the same ``index`` plain
    decode would have used, so identical logits yield identical tokens."""
    greedy = jnp.argmax(lg).astype(jnp.int32)
    # top-k: keep logits >= the k-th largest (k == 0 -> keep all);
    # the k-th largest comes from a static-size top_k, not a full sort
    kth = jax.lax.top_k(lg, cap)[0][jnp.clip(k, 1, cap) - 1]
    masked = jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)
    key = jax.random.fold_in(jax.random.PRNGKey(s), idx)
    g = jax.random.gumbel(key, lg.shape, lg.dtype)
    sampled = jnp.argmax(masked / jnp.maximum(t, 1e-6) + g)
    return jnp.where(t > 0, sampled.astype(jnp.int32), greedy)


def sample_tokens(logits, temperature, top_k, seed, index, k_cap: int = 0):
    """Sample one token per slot.

    logits: (B, vocab) f32; temperature: (B,) f32 — ``<= 0`` means greedy
    (argmax, the default); top_k: (B,) int32 — ``0`` disables the top-k
    filter; seed: (B,) uint32 per-request PRNG seed; index: (B,) int32
    per-request token index (``len(req.out)``); k_cap: static bound on the
    batch's largest top_k (``0`` -> full vocab — the caller passes the
    pow-2 roundup of ``max(top_k)`` to keep the threshold scan cheap).
    Returns (B,) int32.
    """
    v = logits.shape[-1]
    cap = v if k_cap <= 0 else min(k_cap, v)
    return jax.vmap(
        lambda lg, t, k, s, idx: _sample_one(lg, t, k, s, idx, cap)
    )(logits, temperature, top_k, seed, index)


def verify_tokens(logits, temperature, top_k, seed, index0, k_cap: int = 0):
    """Sample all W verify positions of every slot in one executable.

    logits: (B, W, vocab) f32 from ``transformer.verify_step``; position j
    of slot b samples with token index ``index0[b] + j`` — the index plain
    decode would reach after accepting j tokens — and the request's own
    (temperature, top_k, seed), so the returned (B, W) int32 grid holds,
    at every j, *the* token sequential decoding of the draft prefix would
    emit.  The engine accepts draft token j+1 iff it equals entry j (and
    always emits entry ``n_accepted - 1`` as the bonus/correction token):
    token-identity with plain decode holds for greedy and seeded sampling
    alike, because :func:`_sample_one` is deterministic in
    (seed, index, logits).
    """
    v = logits.shape[-1]
    W = logits.shape[1]
    cap = v if k_cap <= 0 else min(k_cap, v)
    idx = index0[:, None] + jnp.arange(W, dtype=index0.dtype)

    def row(lg, t, k, s, idxs):
        return jax.vmap(
            lambda l, i: _sample_one(l, t, k, s, i, cap))(lg, idxs)

    return jax.vmap(row)(logits, temperature, top_k, seed, idx)

"""Paged KV-cache bookkeeping: page pool sizing, per-slot page tables, and a
host-side page allocator.

FAMOUS banks its attention operands into fixed-size BRAM tiles so one
synthesis serves many shapes; the serving analogue is a *paged* KV cache:
every global-attention layer shares one pool of fixed-size pages
``(n_pages, page_size, kv_heads, head_dim)`` and each slot owns a list of
page ids (its *page table*) instead of a contiguous ``max_seq`` stripe.
HBM then scales with live tokens (``sum(ceil(len/page_size))`` pages), not
with ``n_slots x max_seq``, so a single long-context request can coexist
with many short ones in the same pool.

Allocator invariants (checked by tests/test_paged.py):

  * page 0 is the *null page* — never handed out, it absorbs writes from
    inactive slots and padded prefill chunks; masked reads never see it.
  * a live page id appears in exactly one slot's table (no aliasing).
  * ``free(slot)`` returns every page of the slot and zeroes its table row.
  * allocation beyond capacity raises :class:`PagePoolExhausted` and leaves
    the allocator state untouched (clean admission control).

The allocator is deliberately host-side (numpy): page ids change at request
granularity, orders of magnitude slower than the decode step, and feeding
the jitted decode as a plain ``(n_slots, pages_per_slot)`` int32 operand
keeps one executable for every request mix (the paper's "reprogram the µB,
never re-synthesise").
"""
from __future__ import annotations

import dataclasses

import numpy as np

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Admission-control error: the page pool cannot back the request."""


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged KV cache."""

    page_size: int = 16          # tokens per page (the banking granularity)
    n_pages: int = 0             # total pool pages incl. the null page

    def pages_per_slot(self, max_seq: int) -> int:
        return -(-max_seq // self.page_size)    # ceil

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @staticmethod
    def default_pool(n_slots: int, max_seq: int, page_size: int) -> int:
        """Pool sized to back a full batch of max-length sequences, plus the
        null page — the drop-in-capacity baseline.  Callers oversubscribe by
        passing a smaller ``n_pages`` explicitly."""
        return 1 + n_slots * (-(-max_seq // page_size))


class PageAllocator:
    """Free-list allocator over page ids ``1..n_pages-1`` (0 is null)."""

    def __init__(self, cfg: PagedCacheConfig, n_slots: int, max_seq: int):
        assert cfg.n_pages >= 2, "pool needs the null page plus one real page"
        self.cfg = cfg
        self.n_slots = n_slots
        self.pages_per_slot = cfg.pages_per_slot(max_seq)
        self._free = list(range(cfg.n_pages - 1, 0, -1))  # pop() -> low ids
        # slot page tables; row s lists the pages of slot s, NULL_PAGE-padded.
        self.page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._n_held = np.zeros((n_slots,), np.int32)
        # bumped on every table mutation so callers can cache derived state
        # (e.g. the device copy of the page table) and re-upload only when
        # allocation actually changed
        self.version = 0

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_held(self, slot: int) -> int:
        return int(self._n_held[slot])

    def can_admit(self, n_tokens: int) -> bool:
        return self.cfg.pages_for(max(n_tokens, 1)) <= self.free_pages

    # -- mutation -----------------------------------------------------------
    def grow(self, slot: int, n_tokens: int) -> None:
        """Ensure slot ``slot`` holds enough pages for ``n_tokens`` tokens.
        Raises :class:`PagePoolExhausted` (state untouched) if it cannot."""
        need = self.cfg.pages_for(n_tokens)
        if need > self.pages_per_slot:
            raise PagePoolExhausted(
                f"{n_tokens} tokens need {need} pages, over the per-slot "
                f"cap of {self.pages_per_slot} (max_seq)")
        held = int(self._n_held[slot])
        short = need - held
        if short <= 0:
            return
        if short > len(self._free):
            raise PagePoolExhausted(
                f"slot {slot} needs {short} more page(s) for {n_tokens} "
                f"tokens; {len(self._free)} free of "
                f"{self.cfg.n_pages - 1} allocatable")
        for j in range(held, need):
            self.page_table[slot, j] = self._free.pop()
        self._n_held[slot] = need
        self.version += 1

    def free(self, slot: int) -> None:
        """Retire a slot: return its pages and zero its table row."""
        for j in range(int(self._n_held[slot])):
            self._free.append(int(self.page_table[slot, j]))
        self.page_table[slot, :] = NULL_PAGE
        self._n_held[slot] = 0
        self.version += 1

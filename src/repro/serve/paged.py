"""Paged KV-cache bookkeeping: page pool sizing, per-slot page tables, a
host-side page allocator with per-page refcounts, and the prefix-cache
index that lets requests share identical prompt blocks.

FAMOUS banks its attention operands into fixed-size BRAM tiles so one
synthesis serves many shapes; the serving analogue is a *paged* KV cache:
every global-attention layer shares one pool of fixed-size pages
``(n_pages, page_size, kv_heads, head_dim)`` and each slot owns a list of
page ids (its *page table*) instead of a contiguous ``max_seq`` stripe.
HBM then scales with live tokens (``sum(ceil(len/page_size))`` pages), not
with ``n_slots x max_seq``, so a single long-context request can coexist
with many short ones in the same pool.

Prefix caching takes the reuse one rung further: the *contents* of a page
are a pure function of the token block it holds plus everything before it,
so identical prompt prefixes (shared system prompts, few-shot preambles)
can alias the same physical pages across slots.  The allocator keeps

  * a per-page **refcount** — a page may appear in several slots' tables;
    aliased pages are read-only by construction (the engine only ever maps
    *full* prompt blocks, and every write lands at positions past the
    mapped prefix, i.e. in the slot's private tail pages — copy-on-write
    degenerates to copy-never because the partial last block is always
    prefilled privately);
  * a **content-hash index** ``block hash -> page id`` over published
    pages (the engine publishes a request's full prompt blocks when it
    retires);
  * a **cached-free LRU**: pages whose refcount drops to 0 but that are
    still indexed.  They stay warm for future hits yet count as free
    capacity — allocation reclaims the oldest on demand (evicting its
    index entry), so a warm cache never blocks admission.

Allocator invariants (checked by tests/test_paged.py and
tests/test_prefix_cache.py via :meth:`PageAllocator.assert_invariants`):

  * page 0 is the *null page* — never handed out, it absorbs writes from
    inactive slots and padded prefill chunks; masked reads never see it.
  * every allocatable page is in exactly one of three states: on the free
    list, on the cached-free LRU (refcount 0, indexed), or live
    (refcount >= 1).
  * a page's refcount equals the number of slot tables holding it.
  * non-null writes only ever target pages with refcount 1 that sit past
    the slot's shared prefix (the engine's COW rule).
  * ``free(slot)`` drops one reference per held page and zeroes the table
    row; pages reaching refcount 0 return to the free list, or to the
    cached-free LRU if indexed.
  * allocation beyond capacity raises :class:`PagePoolExhausted` and
    leaves the allocator state untouched (clean admission control).

The allocator is deliberately host-side (numpy): page ids change at request
granularity, orders of magnitude slower than the decode step, and feeding
the jitted decode as a plain ``(n_slots, pages_per_slot)`` int32 operand
keeps one executable for every request mix (the paper's "reprogram the µB,
never re-synthesise").
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import numpy as np

from repro.obs.runtime import NULL_OBSERVER

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Admission-control error: the page pool cannot back the request."""


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static geometry of the paged KV cache."""

    page_size: int = 16          # tokens per page (the banking granularity)
    n_pages: int = 0             # total pool pages incl. the null page

    def pages_per_slot(self, max_seq: int) -> int:
        return -(-max_seq // self.page_size)    # ceil

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @staticmethod
    def default_pool(n_slots: int, max_seq: int, page_size: int) -> int:
        """Pool sized to back a full batch of max-length sequences, plus the
        null page — the drop-in-capacity baseline.  Callers oversubscribe by
        passing a smaller ``n_pages`` explicitly."""
        return 1 + n_slots * (-(-max_seq // page_size))


def block_hashes(tokens, page_size: int) -> list:
    """Chained content hashes of the full ``page_size`` token blocks of
    ``tokens`` (the partial tail block is never hashed: it is never
    shareable).  Block j's hash covers blocks 0..j, so equal hashes imply
    equal *prefixes* — a page's K/V content is a pure function of its hash.
    """
    out = []
    digest = b""
    for j in range(len(tokens) // page_size):
        blk = np.asarray(tokens[j * page_size:(j + 1) * page_size],
                         np.int64).tobytes()
        digest = hashlib.blake2b(digest + blk, digest_size=16).digest()
        out.append(digest)
    return out


class PageAllocator:
    """Refcounting free-list allocator over page ids ``1..n_pages-1``
    (0 is null), with a prefix-cache index over published pages."""

    def __init__(self, cfg: PagedCacheConfig, n_slots: int, max_seq: int,
                 observer=None):
        assert cfg.n_pages >= 2, "pool needs the null page plus one real page"
        self.cfg = cfg
        # observability seam (repro.obs.runtime): page-op counters, pool
        # gauges, trace instants.  Host-pure — hooks take plain ints.
        self.obs = observer if observer is not None else NULL_OBSERVER
        self.n_slots = n_slots
        self.pages_per_slot = cfg.pages_per_slot(max_seq)
        self._free = list(range(cfg.n_pages - 1, 0, -1))  # pop() -> low ids
        # slot page tables; row s lists the pages of slot s, NULL_PAGE-padded.
        self.page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._n_held = np.zeros((n_slots,), np.int32)
        # leading pages of each slot that are *shared* (refcount may be > 1;
        # read-only — all writes land past them)
        self._n_shared = np.zeros((n_slots,), np.int32)
        self._ref = np.zeros((cfg.n_pages,), np.int32)
        # prefix cache: block hash -> page id, inverse map, and the LRU of
        # refcount-0-but-still-indexed pages (reclaimed oldest-first)
        self._index: dict = {}
        self._page_hash: dict = {}
        self._lru: OrderedDict = OrderedDict()
        # bumped on every table mutation so callers can cache derived state
        # (e.g. the device copy of the page table) and re-upload only when
        # allocation actually changed
        self.version = 0

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Pages allocatable right now: truly free plus cached-free (the
        LRU is reclaimed on demand, so a warm cache never blocks)."""
        return len(self._free) + len(self._lru)

    @property
    def cached_free_pages(self) -> int:
        return len(self._lru)

    def pages_held(self, slot: int) -> int:
        return int(self._n_held[slot])

    def pages_shared(self, slot: int) -> int:
        return int(self._n_shared[slot])

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def can_admit(self, n_tokens: int, hits=()) -> bool:
        """Would ``grow`` succeed for a fresh ``n_tokens`` admission whose
        leading blocks hit the cached pages ``hits``?  Cached-free hits are
        about to be pinned, so they cannot double as fresh capacity."""
        need = self.cfg.pages_for(max(n_tokens, 1)) - len(hits)
        avail = self.free_pages - sum(1 for p in hits if self._ref[p] == 0)
        return need <= avail

    # -- prefix cache --------------------------------------------------------
    def lookup(self, hashes) -> list:
        """Longest run of consecutive index hits from block 0 (a chained
        hash only makes sense as a prefix).  Returns the hit page ids."""
        pages = []
        for h in hashes:
            page = self._index.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def map_prefix(self, slot: int, pages) -> None:
        """Alias cached ``pages`` (from :meth:`lookup`) into the head of an
        empty slot's table, pinning each (refcount += 1; off the LRU)."""
        assert self._n_held[slot] == 0, (slot, self._n_held[slot])
        assert len(pages) <= self.pages_per_slot
        for j, page in enumerate(pages):
            self.page_table[slot, j] = page
            self._ref[page] += 1
            self._lru.pop(page, None)
        self._n_held[slot] = len(pages)
        self._n_shared[slot] = len(pages)
        self.version += 1

    def publish(self, slot: int, hashes) -> None:
        """Index the slot's leading pages under ``hashes`` (one per full
        prompt block) so future admissions can alias them.  Blocks whose
        hash is already indexed are skipped — the existing page wins (this
        slot's duplicate simply frees normally)."""
        n = min(len(hashes), int(self._n_held[slot]))
        row = self.page_table[slot, :n].tolist()   # one pull, not n
        published = 0
        for j in range(n):
            h = hashes[j]
            if h in self._index:
                continue
            page = row[j]
            self._index[h] = page
            self._page_hash[page] = h
            published += 1
        self.obs.on_page_event("publish", slot, published)

    def _take_page(self) -> int:
        """A fresh page: off the free list, else reclaim the LRU-oldest
        cached-free page (evicting its index entry)."""
        if self._free:
            return self._free.pop()
        page, _ = self._lru.popitem(last=False)
        del self._index[self._page_hash.pop(page)]
        self.obs.on_page_event("evict", -1, 1)
        return page

    # -- mutation -----------------------------------------------------------
    def grow(self, slot: int, n_tokens: int) -> None:
        """Ensure slot ``slot`` holds enough pages for ``n_tokens`` tokens.
        New pages are private (refcount 1).  Raises
        :class:`PagePoolExhausted` (state untouched) if it cannot."""
        need = self.cfg.pages_for(n_tokens)
        if need > self.pages_per_slot:
            raise PagePoolExhausted(
                f"{n_tokens} tokens need {need} pages, over the per-slot "
                f"cap of {self.pages_per_slot} (max_seq)")
        held = int(self._n_held[slot])
        short = need - held
        if short <= 0:
            return
        if short > self.free_pages:
            raise PagePoolExhausted(
                f"slot {slot} needs {short} more page(s) for {n_tokens} "
                f"tokens; {self.free_pages} free of "
                f"{self.cfg.n_pages - 1} allocatable")
        for j in range(held, need):
            page = self._take_page()
            self.page_table[slot, j] = page
            self._ref[page] = 1
        self._n_held[slot] = need
        self.version += 1
        self.obs.on_page_event("grow", slot, short)
        self.obs.on_pool(self.free_pages, len(self._lru))

    def shrink(self, slot: int, n_tokens: int) -> None:
        """Speculative rollback: drop the slot's tail pages beyond
        ``pages_for(n_tokens)``.  The engine grows a slot for its full
        draft before verifying; pages grown for *rejected* draft tokens
        come back here (no leak when a draft is cut at a page boundary).
        Never cuts into the shared prefix, and handles tail pages exactly
        like :meth:`free` (a just-reclaimed-from-LRU page is unindexed, so
        live private tails always return to the free list)."""
        keep = max(self.cfg.pages_for(max(n_tokens, 1)),
                   int(self._n_shared[slot]))
        held = int(self._n_held[slot])
        if held <= keep:
            return
        for page in self.page_table[slot, keep:held][::-1].tolist():
            self._ref[page] -= 1
            if self._ref[page] == 0:
                if page in self._page_hash:
                    self._lru[page] = None
                else:
                    self._free.append(page)
        self.page_table[slot, keep:held] = NULL_PAGE
        self._n_held[slot] = keep
        self.version += 1
        self.obs.on_page_event("shrink", slot, held - keep)
        self.obs.on_pool(self.free_pages, len(self._lru))

    def free(self, slot: int) -> None:
        """Retire a slot: drop one reference per held page and zero its
        table row.  Pages reaching refcount 0 return to the free list —
        or to the cached-free LRU if they are still indexed.  Deep blocks
        park *older* on the LRU than head blocks: a chained-prefix lookup
        stops at its first miss, so under reclaim pressure a prefix must
        be eaten from its deep end — evicting block 0 first would leave
        an unreachable suffix warm and the whole prefix cold."""
        held = int(self._n_held[slot])
        for page in self.page_table[slot, :held][::-1].tolist():  # one pull
            self._ref[page] -= 1
            if self._ref[page] == 0:
                if page in self._page_hash:
                    self._lru[page] = None       # most-recently-used end
                else:
                    self._free.append(page)
        self.page_table[slot, :] = NULL_PAGE
        self._n_held[slot] = 0
        self._n_shared[slot] = 0
        self.version += 1
        self.obs.on_page_event("free", slot, held)
        self.obs.on_pool(self.free_pages, len(self._lru))

    # -- debug --------------------------------------------------------------
    def assert_invariants(self) -> None:
        """Exhaustive state check (tests; O(pool), not for the hot loop)."""
        free, lru = set(self._free), set(self._lru)
        live = {p for p in range(1, self.cfg.n_pages) if self._ref[p] > 0}
        assert not (free & lru) and not (free & live) and not (lru & live), \
            (free & lru, free & live, lru & live)
        assert free | lru | live == set(range(1, self.cfg.n_pages))
        assert self._ref[NULL_PAGE] == 0
        counts = np.zeros_like(self._ref)
        for s in range(self.n_slots):
            held = int(self._n_held[s])
            assert 0 <= self._n_shared[s] <= held
            for page in self.page_table[s, :held].tolist():
                assert page != NULL_PAGE
                counts[page] += 1
            assert (self.page_table[s, held:] == NULL_PAGE).all()
        assert (counts == self._ref).all(), (counts, self._ref)
        for page in lru:
            assert page in self._page_hash
        for h, page in self._index.items():
            assert self._page_hash.get(page) == h

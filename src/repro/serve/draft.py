"""Draft-model-free speculative drafting: prompt-lookup / n-gram proposals.

Pure host-side policy, like the Scheduler — the "no jax" contract is
machine-enforced by lint rule RA004 (``repro.analysis.lint``), with no
baseline escape hatch.  Drafting runs between device steps on plain python
lists, so a drafter can never add a compilation or a device sync to the
hot loop.

The idea (ROADMAP: Peng et al.'s length-adaptive co-design applied to
decode): when output structure is predictable — quoting the prompt,
repeating a generated pattern, boilerplate — the *sequence itself* is a
free draft model.  :class:`PromptLookupDrafter` matches the longest
trailing n-gram of ``prompt + generated history`` against an earlier
occurrence in the same sequence and proposes the tokens that followed it.
The engine then verifies all proposed tokens in ONE batched forward
(``transformer.verify_step``) and accepts the longest matching prefix:
every accepted draft token skips a full sequential decode step, and a
fully-rejected draft still yields the one token plain decode would have
produced (speculative serving is token-identical by construction — see
docs/serving.md).

A drafter is any object with ``draft(seq, k) -> list`` proposing up to
``k`` continuation tokens of ``seq``; the engine treats drafting as
best-effort and surfaces drafter exceptions as per-request errors
(``req.error``) rather than letting one poisoned request take down the
batch.
"""
from __future__ import annotations

from repro.obs.runtime import NULL_OBSERVER


class PromptLookupDrafter:
    """Propose the continuation of the most recent earlier occurrence of
    the sequence's trailing n-gram (longest n first).

    ``max_ngram`` trades match precision against hit rate: longer n-grams
    misfire less (higher acceptance per draft) but match less often.
    Proposals are capped at ``k`` tokens by the caller — the engine passes
    ``min(draft_k, tokens the request may still emit)``.
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 observer=None):
        assert 1 <= min_ngram <= max_ngram, (min_ngram, max_ngram)
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        # observability seam (repro.obs.runtime — jax-free, so the RA004
        # purity contract holds transitively): lookup hit rate + volume
        self.obs = observer if observer is not None else NULL_OBSERVER

    def draft(self, seq, k: int) -> list:
        """Up to ``k`` proposed continuation tokens of ``seq`` (prompt +
        generated history, most recent last); ``[]`` when nothing matches.
        """
        out = self._lookup(seq, k)
        self.obs.on_draft_lookup(bool(out), len(out))
        return out

    def _lookup(self, seq, k: int) -> list:
        n_seq = len(seq)
        if k <= 0 or n_seq < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, n_seq - 1), self.min_ngram - 1, -1):
            pat = list(seq[-n:])
            # most recent earlier occurrence that has a continuation
            # (i + n < n_seq); the trailing n-gram itself is excluded by
            # the range bound
            for i in range(n_seq - n - 1, -1, -1):
                if list(seq[i:i + n]) == pat:
                    return [int(t) for t in seq[i + n:i + n + k]]
        return []

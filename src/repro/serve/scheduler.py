"""Pure-policy scheduler for the serving engine (no jax, no device state).

The "no jax" contract is machine-enforced: lint rule RA004
(``repro.analysis.lint``) fails the build if this module ever imports
``jax``/``jax.numpy``, with no baseline escape hatch.

The engine is split into two layers:

  * **Scheduler** (this module) — *decides*.  Owns the request queues,
    per-slot lifecycle state, the per-step token budget, chunked-prefill
    interleaving with decode, youngest-first preemption choice and
    fairness accounting.  Plain host-side python: policy changes never
    touch an executable.
  * **Runtime** (:class:`repro.serve.engine.ServingEngine`) — *executes*.
    Owns params, caches, the page allocator and exactly two hot
    executables: one fixed-shape prefill chunk and one decode step.

Each engine step asks the scheduler for a :class:`StepPlan`: which
prefill chunks to run (slot, start offset, number of real tokens) and
which slots decode.  Budgeting: every decoding slot consumes one token of
the per-step budget; what remains is spent on prefill chunks of
``chunk`` tokens, oldest admission first.  A long prompt therefore
prefills one budget-sized chunk at a time *between* decode steps —
bounding everyone's TPOT — instead of stalling every decode slot
head-of-line while it prefills monolithically.  At least one chunk is
always granted when prefill work exists (forward progress even when
``token_budget < n_decode + chunk``).

The default budget ``n_slots * decode_width + chunk`` yields exactly one
prefill chunk per step while decodes are active, and ``budget // chunk``
chunks per step on an otherwise idle engine (fastest possible TTFT).
``decode_width`` is 1 for plain decode; the speculative engine sets it to
``draft_k + 1`` so every decoding slot is charged the verify executable's
true fixed-shape cost.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.runtime import NULL_OBSERVER

FREE = "free"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    chunk: int = 32        # fixed prefill-chunk shape (the ONE prefill executable)
    token_budget: int = 0  # per-step token target; 0 -> n_slots*width + chunk
    # tokens a decoding slot consumes per step.  Plain decode: 1.
    # Speculative decode: draft_k + 1 — the verify executable is fixed-shape,
    # so a decoding slot costs its full draft width whether or not the
    # drafter proposed anything (short drafts ride as pad rows), and the
    # budget must charge for it or prefill chunks get crowded in under the
    # true compute cost of the step.
    decode_width: int = 1


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    slot: int
    start: int   # absolute offset of the chunk's first token
    n: int       # real tokens in this chunk (<= chunk; the rest is pad)


@dataclasses.dataclass
class StepPlan:
    chunks: list
    decode_slots: list


@dataclasses.dataclass
class SlotInfo:
    req: object = None
    admit_seq: int = -1
    state: str = FREE
    target: int = 0   # tokens to prefill (prompt + resumed output - 1)
    done: int = 0     # tokens prefilled so far


class Scheduler:
    def __init__(self, n_slots: int, cfg: SchedulerConfig = SchedulerConfig(),
                 observer=None):
        assert cfg.chunk >= 1
        self.cfg = cfg
        # the injectable observability seam (repro.obs.runtime — jax-free
        # like this module, so the RA004 purity contract holds transitively)
        self.obs = observer if observer is not None else NULL_OBSERVER
        self.slots = [SlotInfo() for _ in range(n_slots)]
        self.pending: list = []   # fresh requests, FIFO
        self.resume: list = []    # preempted requests — re-enter ahead of fresh
        self.step_count = 0
        self._admit_counter = 0
        # fairness accounting, per request id
        self.stats: dict = {}

    # -- queues ---------------------------------------------------------------
    def enqueue(self, req, *, front: bool = False) -> None:
        (self.resume if front else self.pending).append(req)
        st = self._stats(req)
        st.setdefault("enqueue_step", self.step_count)
        self.obs.on_enqueue(req.rid)
        self.obs.on_queue_depth(len(self.resume) + len(self.pending))

    def next_queued(self):
        q = self.resume if self.resume else self.pending
        return q[0] if q else None

    def pop_queued(self):
        q = self.resume if self.resume else self.pending
        req = q.pop(0)
        self.obs.on_queue_depth(len(self.resume) + len(self.pending))
        return req

    @property
    def has_queued(self) -> bool:
        return bool(self.resume or self.pending)

    @property
    def busy(self) -> bool:
        return any(s.state != FREE for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.state == FREE:
                return i
        return None

    def occupied(self) -> list:
        return [i for i, s in enumerate(self.slots) if s.state != FREE]

    # -- lifecycle ------------------------------------------------------------
    def bind(self, slot: int, req, n_tokens: int, cached: int = 0) -> str:
        """Admit ``req`` (sequence length ``n_tokens``) into ``slot``.
        ``cached`` tokens at the head of the sequence are already resident
        (prefix-cache hit): prefill starts at the first uncached token and
        the saving is charged to the fairness ledger (``cached_tokens``).
        Returns the slot's state: PREFILL (chunks pending) or DECODE
        (nothing left to prefill — single-token, or fully cached)."""
        info = self.slots[slot]
        assert info.state == FREE, (slot, info.state)
        info.req = req
        info.admit_seq = self._admit_counter
        self._admit_counter += 1
        info.target = n_tokens - 1
        info.done = min(cached, info.target)
        info.state = PREFILL if info.done < info.target else DECODE
        st = self._stats(req)
        st["admit_step"] = self.step_count
        if info.done:
            st["cached_tokens"] = st.get("cached_tokens", 0) + info.done
        self.obs.on_admit(req.rid, slot, n_tokens, info.done)
        return info.state

    def mark_prefilled(self, slot: int) -> None:
        """Monolithic path: the whole prompt prefilled at admission."""
        info = self.slots[slot]
        info.done = info.target
        info.state = DECODE

    def on_chunk(self, slot: int, n: int) -> bool:
        """Record ``n`` prefilled tokens; True when prefill completed (the
        slot flips to DECODE and starts decoding next step)."""
        info = self.slots[slot]
        info.done += n
        self._stats(info.req)["prefill_tokens"] = \
            self._stats(info.req).get("prefill_tokens", 0) + n
        self.obs.on_prefill_tokens(n)
        if info.done >= info.target:
            info.state = DECODE
            return True
        return False

    def on_decode_token(self, slot: int) -> None:
        st = self._stats(self.slots[slot].req)
        st["decode_tokens"] = st.get("decode_tokens", 0) + 1
        st.setdefault("first_token_step", self.step_count)

    def on_draft(self, slot: int, drafted: int, accepted: int) -> None:
        """Speculative accounting: ``drafted`` proposed tokens were
        verified this step and ``accepted`` of them survived (the bonus
        token is charged through :meth:`on_decode_token` like any other)."""
        st = self._stats(self.slots[slot].req)
        st["drafted_tokens"] = st.get("drafted_tokens", 0) + drafted
        st["accepted_tokens"] = st.get("accepted_tokens", 0) + accepted

    def release(self, slot: int):
        """Retire / fail / preempt: free the slot, return its request."""
        info = self.slots[slot]
        req = info.req
        self.slots[slot] = SlotInfo()
        return req

    def preempt(self, slot: int):
        """Release + account a preemption; the caller re-enqueues (front)."""
        st = self._stats(self.slots[slot].req)
        st["preemptions"] = st.get("preemptions", 0) + 1
        self.obs.on_preempt(self.slots[slot].req.rid, slot)
        return self.release(slot)

    def preempt_victim(self, exclude=()) -> Optional[int]:
        """Youngest occupied slot by admission order (prefilling or
        decoding) — the cheapest work to throw away and redo."""
        cands = [i for i in self.occupied() if i not in exclude]
        if not cands:
            return None
        return max(cands, key=lambda i: self.slots[i].admit_seq)

    # -- planning -------------------------------------------------------------
    def plan(self) -> StepPlan:
        """One step's worth of work under the token budget."""
        decode_slots = [i for i, s in enumerate(self.slots)
                        if s.state == DECODE]
        budget = self.cfg.token_budget or (
            len(self.slots) * self.cfg.decode_width + self.cfg.chunk)
        left = budget - len(decode_slots) * self.cfg.decode_width
        chunks: list = []
        prefilling = sorted((s.admit_seq, i) for i, s in enumerate(self.slots)
                            if s.state == PREFILL)
        for _, i in prefilling:        # oldest first: finish before starting
            info = self.slots[i]
            done = info.done
            while done < info.target and (left >= self.cfg.chunk
                                          or not chunks):
                n = min(self.cfg.chunk, info.target - done)
                chunks.append(PrefillChunk(slot=i, start=done, n=n))
                done += n
                left -= self.cfg.chunk   # a chunk costs its full shape
            if left < self.cfg.chunk and chunks:
                break
        return StepPlan(chunks=chunks, decode_slots=decode_slots)

    def tick(self) -> None:
        self.step_count += 1

    # -- accounting -----------------------------------------------------------
    def _stats(self, req) -> dict:
        return self.stats.setdefault(req.rid, {})

    def fairness(self, rid) -> dict:
        """Per-request accounting: queueing delay, TTFT in steps, work done,
        prefix-cache savings (``cached_tokens``), preemption count — the
        host-side ledger behind the TTFT/TPOT percentiles in
        benchmarks/serving_bench.py."""
        st = dict(self.stats.get(rid, {}))
        if "enqueue_step" in st and "first_token_step" in st:
            st["ttft_steps"] = st["first_token_step"] - st["enqueue_step"]
        return st

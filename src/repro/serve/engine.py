"""Serving engine, split into Scheduler (policy) + Runtime (this class).

The paper's runtime-programmability story (§IV-C) taken to its serving
conclusion: the Runtime owns exactly **two** hot executables —

  * one **fixed-shape chunked-prefill step** (``transformer.prefill_chunk``:
    ``chunk`` tokens of one slot, at a runtime offset, written straight
    into the slot's rows/pages of the batched caches), and
  * one **decode step** (batch = ``n_slots``, the synthesis-time maximum)
    — or, with ``speculative=True``, one fixed-width **verify step**
    (``transformer.verify_step``, width ``draft_k + 1``) that replaces it:
    a host-side prompt-lookup drafter proposes tokens, the verify forward
    scores all of them at once, and the engine accepts the longest
    matching prefix (token-identical to plain decode; see docs/serving.md),

so compilation count is O(1) for *any* prompt-length mix — no pow-2
prefill-bucket family, no per-length executables for recurrent
architectures.  Everything that varies per request — slot, offset, chunk
fill, lengths, page tables, sampling params — arrives as plain integer
operands: the TPU analogue of "reprogram the µB's loop bounds, never
re-synthesise".

All *policy* — admission, the per-step token budget, chunked-prefill
interleaving with decode, youngest-first preemption, fairness accounting —
lives in the pure-python :class:`~repro.serve.scheduler.Scheduler`.  Each
:meth:`step` executes one :class:`~repro.serve.scheduler.StepPlan`:
budgeted prefill chunks first, then one batched decode across the
decoding slots.  A long prompt thus prefills between other requests'
decode steps (no head-of-line blocking), and prompts are no longer
limited to what one prefill call can hold — only by cache capacity
(``max_seq``).

``prefill_mode="monolithic"`` keeps the legacy whole-prompt-at-admission
path (pow-2 bucketed, exact-length for recurrent archs) as the
comparison baseline for parity tests and benchmarks.

KV-cache layout remains a config switch (``cache_kind``): ``"contiguous"``
per-slot stripes or the ``"paged"`` shared pool with host-side
:class:`~repro.serve.paged.PageAllocator` admission control (see
docs/serving.md and serve/paged.py).  ``prefix_cache=True`` (paged +
chunked only) additionally aliases identical full prompt blocks across
requests through the allocator's refcounted content-hash index — admission
maps cached blocks straight into the page table and prefill starts at the
first uncached token; retirement publishes the request's prompt blocks
onto the cached-free LRU.  Architectures with per-slot recurrent or ring
state fall back to cold prefill (``prefix_cache_active`` False).

Observability is one injectable seam: pass
``observer=repro.obs.Observer(...)`` and every layer — engine step
phases, scheduler queues, page allocator, drafter — reports into its
metrics registry and (optionally) its Perfetto tracer, host-side only
and token-identical to the un-observed engine (docs/observability.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig
from repro.core.famous import FamousConfig
from repro.core.flexible import next_pow2
from repro.models import transformer
from repro.obs.runtime import NULL_OBSERVER
from repro.obs.trace import now as _clock
from repro.parallel import sharding as shardlib
from repro.serve import sampling
from repro.serve.draft import PromptLookupDrafter
from repro.serve.paged import (PageAllocator, PagedCacheConfig,
                               PagePoolExhausted, block_hashes)
from repro.serve.scheduler import (DECODE, FREE, PREFILL, Scheduler,
                                   SchedulerConfig)


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list
    max_new: int = 16
    # per-request sampling params: temperature <= 0 -> greedy (default);
    # top_k == 0 -> full-vocab; seeded runs are reproducible regardless of
    # batch composition / slot placement (see serve/sampling.py).  seed=None
    # falls back to the request id, so unseeded sampling requests draw
    # *different* noise instead of all sharing seed 0.
    temperature: float = 0.0
    top_k: int = 0
    seed: Optional[int] = None
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None  # set when the page pool can never back it
    # wall-clock marks for TTFT/TPOT accounting, set by the engine from the
    # single monotonic clock source (repro.obs.trace.now — the repo's one
    # time.perf_counter call site, shared with trace timestamps)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


def _jit_cache_size(fn) -> int:
    try:
        return fn._cache_size()
    except Exception:                      # pragma: no cover - jax-internal API
        return -1


class ServingEngine:
    """The Runtime: executes the Scheduler's plans against device state."""

    def __init__(self, params, cfg: ModelConfig, fcfg: FamousConfig,
                 n_slots: int = 4, max_seq: int = 256, dtype=jnp.float32,
                 cache_kind: str = "contiguous", page_size: int = 16,
                 n_pages: Optional[int] = None,
                 prefill_mode: str = "chunked", chunk: int = 32,
                 token_budget: int = 0, prefix_cache: bool = False,
                 speculative: bool = False, draft_k: int = 4,
                 drafter=None, kv_dtype: str = "fp",
                 mesh=None, sharding_rules=None, observer=None):
        """``observer``: optional :class:`repro.obs.runtime.Observer` —
        the one injectable seam every layer (engine, scheduler, page
        allocator, drafter) reports to: TTFT/TPOT histograms, queue
        depth, pool utilisation, prefix/speculation counters, the
        executable census, and (when built with ``trace=True``) per-step
        Perfetto trace events.  ``None`` resolves to the no-op
        :data:`~repro.obs.runtime.NULL_OBSERVER`; an enabled observer
        keeps serving token-identical and adds zero device syncs (all
        hooks take host ints — see docs/observability.md).

        ``mesh``: optional :class:`jax.sharding.Mesh` (see
        ``launch.mesh.make_serving_mesh``) — params and caches are placed
        with NamedShardings (tensor parallelism over attention heads /
        kv heads / FFN hidden on the "model" axis; ``sharding_rules``
        overrides :data:`repro.parallel.sharding.SERVE_TP_RULES`) and the
        hot executables pin their outputs with ``out_shardings`` so caches
        never migrate between steps.  Logits stay replicated, so sampling
        and the host bookkeeping loop are untouched.  ``mesh=None`` (the
        default) is the unsharded single-device baseline, bit-identical to
        the pre-mesh engine."""
        assert cache_kind in ("contiguous", "paged"), cache_kind
        assert prefill_mode in ("chunked", "monolithic"), prefill_mode
        assert kv_dtype in ("fp", "int8"), kv_dtype
        self.obs = observer if observer is not None else NULL_OBSERVER
        if kv_dtype == "int8":
            assert cache_kind == "paged", "kv_dtype='int8' requires paged cache"
        self.params = params
        self.cfg = cfg
        self.fcfg = fcfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.dtype = dtype
        self.cache_kind = cache_kind
        self.kv_dtype = kv_dtype
        self.paged = cache_kind == "paged"
        self.chunked = prefill_mode == "chunked"
        self.chunk = min(chunk, max_seq)
        if self.chunked:
            # pads stay inside the cache (positions < ceil(target/C)*C <=
            # max_seq) and the wkv6 chunked form needs S % min(64, S) == 0
            assert max_seq % self.chunk == 0, (max_seq, self.chunk)
            assert self.chunk <= 64 or self.chunk % 64 == 0, self.chunk
        # -- speculative decoding -------------------------------------------
        # The verify step writes K/V at positions [cache_len, cache_len+W)
        # and rolls back *by bookkeeping only* — rejected positions hold
        # junk that is causally masked and overwritten before it is ever
        # read.  That rollback-for-free argument needs position-addressed
        # storage: sliding-window rings overwrite their OLDEST entries and
        # recurrent state cannot rewind, so (like the prefix cache) only
        # all-global-ATTN stacks run speculatively; other archs fall back
        # to plain decode explicitly (`speculative_active` False).
        assert draft_k >= 1, draft_k
        self.draft_k = draft_k
        all_attn = all(
            k == ATTN for k in tuple(cfg.pattern_unit) + tuple(cfg.tail_layers))
        self.speculative_active = speculative and all_attn
        self.drafter = drafter if drafter is not None else \
            PromptLookupDrafter(observer=self.obs)
        self.spec_steps = 0      # verify steps executed
        self.spec_drafted = 0    # draft tokens proposed to the verifier
        self.spec_accepted = 0   # draft tokens accepted (bonus excluded)
        self.sched = Scheduler(n_slots, SchedulerConfig(
            chunk=self.chunk, token_budget=token_budget,
            decode_width=(draft_k + 1) if self.speculative_active else 1),
            observer=self.obs)
        if self.paged:
            assert max_seq % page_size == 0, (max_seq, page_size)
            if n_pages is None:  # drop-in capacity; pass n_pages to oversubscribe
                n_pages = PagedCacheConfig.default_pool(n_slots, max_seq,
                                                        page_size)
            self.pcfg = PagedCacheConfig(page_size=page_size, n_pages=n_pages)
            self.alloc = PageAllocator(self.pcfg, n_slots, max_seq,
                                       observer=self.obs)
            self.caches = transformer.make_caches(
                cfg, n_slots, max_seq, dtype, cache_kind="paged",
                page_size=page_size, n_pages=n_pages, kv_dtype=kv_dtype)
        else:
            self.caches = transformer.make_caches(cfg, n_slots, max_seq, dtype)
        # -- mesh placement -------------------------------------------------
        # Params and caches are committed to their NamedShardings once, here;
        # the executables below pin cache (and logits) outputs with
        # out_shardings so the placement is a fixed point of every step —
        # GSPMD inserts the only collectives (attention-output + FFN-down
        # all-reduces) inside the steps.  Host-side state (allocator, page
        # tables, cache_len/last_token numpy, scheduler) is device-agnostic:
        # page ids address whole (page_size, kv, dh) rows whose kv dim is
        # what actually shards, so one copy serves every device.
        self.mesh = mesh
        self._jit_kw_caches: dict = {}   # jits returning caches only
        self._jit_kw_logits: dict = {}   # jits returning (logits, caches)
        if mesh is not None:
            rules = sharding_rules or shardlib.SERVE_TP_RULES
            self.params = jax.device_put(
                self.params,
                shardlib.tree_shardings(mesh, transformer.param_axes(cfg),
                                        rules, self.params))
            cshard = shardlib.tree_shardings(
                mesh, transformer.cache_axes(cfg, cache_kind, kv_dtype),
                rules, self.caches)
            self.caches = jax.device_put(self.caches, cshard)
            repl = shardlib.replicated(mesh)
            self._jit_kw_caches = {"out_shardings": cshard}
            self._jit_kw_logits = {"out_shardings": (repl, cshard)}
        # -- prefix cache ---------------------------------------------------
        # Aliasing cached prompt blocks requires (a) paged storage, (b) a
        # chunked prefill that can start at the first uncached token, and
        # (c) an architecture whose *entire* prefix state lives in the page
        # pool.  Sliding-window rings and recurrent state (RG-LRU, RWKV) are
        # per-slot and not content-addressable, so hybrid/recurrent patterns
        # fall back to cold prefill explicitly (`prefix_cache_active` False).
        if prefix_cache:
            assert cache_kind == "paged", "prefix_cache requires paged cache"
            assert prefill_mode == "chunked", \
                "prefix_cache requires chunked prefill (runtime offsets)"
        self.prefix_shareable = all(
            k == ATTN for k in tuple(cfg.pattern_unit) + tuple(cfg.tail_layers))
        self.prefix_cache_active = prefix_cache and self.prefix_shareable
        self.prefix_lookups = 0        # admissions that probed the index
        self.prefix_hit_pages = 0      # pages aliased instead of allocated
        self.prefix_hit_tokens = 0     # tokens whose prefill was skipped
        self._slot_hashes: list[Optional[list]] = [None] * n_slots
        # per-slot sequence state lives on the HOST: slot-granular updates
        # are plain numpy writes (an eager jnp ``.at[].set`` costs a full
        # dispatch each, ~1.3 ms on CPU — more than a tiny-model forward)
        # and the arrays are materialized on device once per launch as
        # ordinary decode-step operands (repro.analysis lint rule RA002)
        self.cache_len = np.zeros((n_slots,), np.int32)
        self.last_token = np.zeros((n_slots,), np.int32)
        self._slot_seq: list[Optional[list]] = [None] * n_slots
        self._failed: list[Request] = []
        self._pt_version = -1          # device page-table cache key
        self._pt_device = None
        # -- the executables ----------------------------------------------
        self._prefill_exec: dict[int, callable] = {}    # monolithic only
        self._prefill_chunk_exec = jax.jit(functools.partial(
            transformer.prefill_chunk, cfg=cfg, fcfg=fcfg),
            **self._jit_kw_caches)
        self._decode = jax.jit(
            functools.partial(transformer.decode_step, cfg=cfg, fcfg=fcfg),
            **self._jit_kw_logits)
        # the speculative path REPLACES decode with one fixed-shape verify
        # executable (batch n_slots, width draft_k+1, per-slot runtime
        # offsets): a zero-draft slot verifies as a 1-valid-token decode,
        # so the census stays at three hot executables either way
        self._verify = jax.jit(
            functools.partial(transformer.verify_step, cfg=cfg, fcfg=fcfg),
            **self._jit_kw_logits)
        self._clear = jax.jit(functools.partial(
            transformer.clear_slot, cfg=cfg, paged=self.paged),
            **self._jit_kw_caches)
        self._sample = jax.jit(sampling.sample_tokens,
                               static_argnames=("k_cap",))
        self._sample_verify = jax.jit(sampling.verify_tokens,
                                      static_argnames=("k_cap",))
        # recurrent state cannot absorb junk pad tokens -> the monolithic
        # path prefills those archs at exact length (chunked masks pads)
        self.bucketed = all(k in (ATTN, LOCAL_ATTN) for k in cfg.pattern_unit)
        # the observer pulls the executable census through this source on
        # every snapshot/exposition, so repro_engine_compilations{exec=...}
        # and `engine.compilations` can never disagree (and retrace_guard
        # accepts either as its census subject)
        self.obs.register_census(lambda: self.compilations)

    # -- compiled helpers ---------------------------------------------------
    def _prefill_fn(self, length: int):
        """Monolithic path: one executable per padded prompt length."""
        if length not in self._prefill_exec:
            def fn(params, tokens, caches, slot, page_ids):
                one = transformer.make_caches(self.cfg, 1, self.max_seq,
                                              self.dtype)
                _, one = transformer.prefill(params, tokens, one, self.cfg,
                                             self.fcfg)
                return transformer.write_prefill_to_slot(
                    caches, one, slot, self.cfg,
                    page_ids=page_ids if self.paged else None)

            self._prefill_exec[length] = jax.jit(fn, **self._jit_kw_caches)
        return self._prefill_exec[length]

    @property
    def prefill_compilations(self) -> int:
        """Compiled prefill executables: O(1) chunked, O(buckets|lengths)
        monolithic."""
        if self.chunked:
            return _jit_cache_size(self._prefill_chunk_exec)
        return len(self._prefill_exec)

    @property
    def compilations(self) -> dict:
        """Executable census (the ≤-3 acceptance check lives on this)."""
        return {
            "prefill": self.prefill_compilations,
            "decode": _jit_cache_size(self._decode),
            "verify": _jit_cache_size(self._verify),
            "clear": _jit_cache_size(self._clear),
        }

    def cache_bytes_per_device(self) -> int:
        """KV/state cache bytes resident on EACH device.  Under a TP mesh
        the kv-head (or FFN-hidden) dims are sharded, so this shrinks to
        ~1/TP of the unsharded total — the memory headroom TP buys for
        bigger models / more pages per device."""
        total = 0
        for leaf in jax.tree_util.tree_leaves(self.caches):
            shard = leaf.sharding.shard_shape(leaf.shape)
            total += int(np.prod(shard)) * leaf.dtype.itemsize
        return total

    @property
    def acceptance_rate(self) -> float:
        """Accepted draft tokens / proposed draft tokens (bonus excluded)."""
        return self.spec_accepted / max(self.spec_drafted, 1)

    @property
    def accepted_per_step(self) -> float:
        """Mean tokens emitted per verify step (1.0 = plain-decode pace)."""
        return ((self.spec_steps + self.spec_accepted)
                / max(self.spec_steps, 1))

    @property
    def slot_req(self) -> list:
        """Requests by slot (None = free) — scheduler state, read-only."""
        return [None if s.state == FREE else s.req for s in self.sched.slots]

    def _page_table(self):
        """Device copy of the page table, re-uploaded only when the
        allocator actually mutated (steady-state decode re-uses it)."""
        if self._pt_version != self.alloc.version:
            self._pt_device = jnp.asarray(self.alloc.page_table)
            self._pt_version = self.alloc.version
        return self._pt_device

    # -- admission ------------------------------------------------------------
    def _prefix_hashes(self, req: Request, n: int):
        """(prompt-block hashes, lookup cap) for an admission of total
        sequence length ``n``.  Only *full* prompt blocks are shareable, and
        only blocks fully inside the first ``n - 1`` tokens may be aliased:
        decode restarts at token ``n - 1`` and writes its K/V, so the page
        holding position ``n - 1`` must always be private (the COW rule —
        the partial last block is prefilled into a fresh page, never
        copied).  Memoized on the request: a request deferred at the queue
        head is probed by ``_admissible`` every step, and its prompt only
        needs hashing once."""
        ps = self.pcfg.page_size
        cached = getattr(req, "_block_hashes", None)
        if cached is None or cached[0] != ps:
            cached = (ps, block_hashes(req.tokens, ps))
            req._block_hashes = cached
        hashes = cached[1]
        return hashes, min(len(hashes), (n - 1) // ps)

    def add_request(self, req: Request) -> int:
        """Admit a request into a free slot.  Paged mode reserves the full
        sequence's prompt pages first; on :class:`PagePoolExhausted` the
        engine state is untouched (clean admission control).  With the
        prefix cache active, every full prompt block that hits the index is
        aliased into the slot's page table instead of allocated+prefilled —
        the scheduler then starts chunked prefill at the first uncached
        token (the runtime-offset chunk executable needs no new compile).

        Chunked mode does **no prefill here** — the scheduler doles the
        prompt out as budget-sized chunks inside :meth:`step`, interleaved
        with everyone else's decode.  Monolithic mode prefills the whole
        prompt now (legacy comparison path).  A preempted request
        (non-empty ``req.out``) resumes identically either way: its full
        prefix (prompt + generated-so-far) is re-prefilled — minus any
        cached head — and decode continues token-identically.
        """
        slot = self.sched.free_slot()
        assert slot is not None, "no free slot"
        seq = list(req.tokens) + list(req.out)
        n = len(seq)
        assert 1 <= n <= self.max_seq
        n_cached = 0
        if self.paged:
            if self.prefix_cache_active:
                hashes, cap = self._prefix_hashes(req, n)
                hits = self.alloc.lookup(hashes[:cap])
                self.prefix_lookups += 1
                self.obs.on_prefix_lookup(req.rid, len(hits),
                                          len(hits) * self.pcfg.page_size)
                if hits:
                    self.alloc.map_prefix(slot, hits)
                    n_cached = len(hits) * self.pcfg.page_size
                    self.prefix_hit_pages += len(hits)
                    self.prefix_hit_tokens += n_cached
                self._slot_hashes[slot] = hashes
            try:
                self.alloc.grow(slot, n)  # PagePoolExhausted if oversize
            except PagePoolExhausted:
                self.alloc.free(slot)     # roll back any mapped prefix
                self._slot_hashes[slot] = None
                raise
        state = self.sched.bind(slot, req, n, cached=n_cached)
        self._slot_seq[slot] = seq
        if req.t_submit is None:
            req.t_submit = _clock()
        if not self.chunked and state == PREFILL:
            m = n - 1
            plen = min(next_pow2(m), self.max_seq) if self.bucketed else m
            toks = np.zeros((1, plen), np.int32)
            toks[0, :m] = seq[:m]
            page_ids = (jnp.asarray(self.alloc.page_table[slot]) if self.paged
                        else jnp.zeros((0,), jnp.int32))
            fn = self._prefill_fn(plen)
            self.caches = fn(self.params, jnp.asarray(toks), self.caches,
                             jnp.int32(slot), page_ids)
            self.sched.mark_prefilled(slot)
            state = DECODE
        if state == DECODE and self.sched.slots[slot].target == 0:
            # nothing to prefill: clear any stale per-slot state
            self.caches = self._clear(self.caches, jnp.int32(slot))
        if state == DECODE:
            # generation restarts at the last prompt token: it is re-decoded
            # so its K/V (or recurrent-state) entry lands at position n-1 —
            # always in a private page, even when everything before it was a
            # cache hit (a fully-cached prompt skips prefill entirely).
            self.cache_len[slot] = n - 1
            self.last_token[slot] = seq[-1]
        else:
            self.cache_len[slot] = n_cached
        return slot

    # -- preemption / page growth ---------------------------------------------
    def _preempt(self, slot: int) -> None:
        """Evict a running sequence: free its pages and queue it for
        re-admission ahead of fresh requests.  Generated tokens stay on the
        request; resuming is just a longer (chunked) prefill — no state is
        copied or swapped out.  Mid-prefill victims simply restart their
        prefill."""
        req = self.sched.preempt(slot)
        self.cache_len[slot] = 0
        self._slot_seq[slot] = None
        self._slot_hashes[slot] = None   # partial prefill: never published
        self.alloc.free(slot)
        self.sched.enqueue(req, front=True)

    def _fail_slot(self, slot: int, err: str) -> None:
        req = self.sched.release(slot)
        req.error, req.done = err, True
        req.t_done = _clock()
        self.cache_len[slot] = 0
        self._slot_seq[slot] = None
        self._slot_hashes[slot] = None
        if self.paged:
            self.alloc.free(slot)
        self.obs.on_retire(req, slot)
        self._failed.append(req)

    def _grow_active(self, active: list) -> list:
        """Reserve the next token's page for every decoding slot, preempting
        youngest-first (decoding *or* prefilling) when the pool runs dry.
        A lone sequence that cannot grow is failed rather than crashing."""
        for i in list(active):
            if i not in active:
                continue
            while True:
                try:
                    self.alloc.grow(i, int(self.cache_len[i]) + 1)
                    break
                except PagePoolExhausted as e:
                    victim = self.sched.preempt_victim()
                    if victim == i and len(self.sched.occupied()) == 1:
                        # nothing left to preempt: the pool can never back
                        # this sequence — fail it cleanly
                        self._fail_slot(i, str(e))
                        active.remove(i)
                        break
                    self._preempt(victim)
                    if victim in active:
                        active.remove(victim)
                    if victim == i:
                        break
        return active

    # -- the step -------------------------------------------------------------
    def step(self):
        """Execute one scheduler plan: budgeted prefill chunks, then one
        batched decode across the decoding slots.  Returns the requests
        that finished (or, paged mode, failed) this step."""
        finished = []
        self.obs.on_step(
            queue_depth=len(self.sched.resume) + len(self.sched.pending),
            occupied=len(self.sched.occupied()))
        plan = self.sched.plan()
        # --- prefill chunks (fixed shape; one executable) -------------------
        if plan.chunks:
            pt = self._page_table() if self.paged else None
            for ch in plan.chunks:
                seq = self._slot_seq[ch.slot]
                toks = np.zeros((1, self.chunk), np.int32)
                toks[0, :ch.n] = seq[ch.start:ch.start + ch.n]
                kw = {"page_table": pt} if self.paged else {}
                with self.obs.phase("prefill_chunk", slot=ch.slot,
                                    rid=self.sched.slots[ch.slot].req.rid,
                                    start=ch.start, n=ch.n):
                    self.caches = self._prefill_chunk_exec(
                        self.params, jnp.asarray(toks), self.caches,
                        jnp.int32(ch.slot), jnp.int32(ch.start),
                        jnp.int32(ch.n), **kw)
                self.cache_len[ch.slot] = ch.start + ch.n
                if self.sched.on_chunk(ch.slot, ch.n):
                    # prefill complete: decode restarts at the last token,
                    # whose K/V entry is then written exactly once at n-1
                    self.last_token[ch.slot] = seq[-1]
        # --- decode ----------------------------------------------------------
        active = list(plan.decode_slots)
        if self.speculative_active:
            self._decode_speculative(active, finished)
        else:
            self._decode_plain(active, finished)
        self.sched.tick()
        return finished

    def _sampling_operands(self, active):
        """Per-slot sampling operands (host numpy, materialized once)."""
        temps = np.zeros((self.n_slots,), np.float32)
        topks = np.zeros((self.n_slots,), np.int32)
        seeds = np.zeros((self.n_slots,), np.uint32)
        idxs = np.zeros((self.n_slots,), np.int32)
        for i in active:
            r = self.sched.slots[i].req
            temps[i] = r.temperature
            topks[i] = r.top_k
            # rids/seeds may exceed 2^31 — fold, don't truncate (uint32)
            seeds[i] = sampling.fold_seed(r.rid if r.seed is None else r.seed)
            idxs[i] = len(r.out)
        return temps, topks, seeds, idxs

    def _maybe_retire(self, i: int, req: Request, now: float,
                      finished: list) -> None:
        """Release the slot when the request hit its length limits."""
        if (len(req.out) >= req.max_new
                or int(self.cache_len[i]) >= self.max_seq - 1):
            req.done = True
            req.t_done = now
            self.obs.on_retire(req, i)
            finished.append(req)
            self.sched.release(i)
            self._slot_seq[i] = None
            self.cache_len[i] = 0
            if self.paged:
                if self.prefix_cache_active and self._slot_hashes[i]:
                    # publish-on-retire: the slot's full prompt blocks
                    # (now completely written) become index entries; its
                    # pages drop to refcount 0 in free() below but stay
                    # warm on the cached-free LRU for future hits
                    self.alloc.publish(i, self._slot_hashes[i])
                self._slot_hashes[i] = None
                self.alloc.free(i)  # refcounts drop; pool or LRU

    def _decode_plain(self, active: list, finished: list) -> None:
        if self.paged and active:
            active = self._grow_active(active)
            finished.extend(self._failed)
            self._failed.clear()
        if not active:
            return
        act = np.zeros((self.n_slots,), bool)
        act[active] = True
        act_dev = jnp.asarray(act)
        kw = {"page_table": self._page_table()} if self.paged else {}
        # host numpy slot state is materialized on device here, once per
        # launch, as plain operands of the (warm) decode executable.  The
        # observer phase wraps dispatch AND the step's one device->host
        # sync, so the span is the true host-observed decode latency.
        with self.obs.phase("decode", slots=len(active)):
            logits, self.caches = self._decode(self.params,
                                               jnp.asarray(self.last_token),
                                               self.caches,
                                               jnp.asarray(self.cache_len),
                                               active=act_dev, **kw)
            temps, topks, seeds, idxs = self._sampling_operands(active)
            if temps.any():
                # k_cap: pow-2 roundup of the largest requested top-k, so
                # the sampler thresholds against a small static top_k
                # instead of a full-vocab sort (<= O(log V) executables)
                k_cap = next_pow2(max(int(topks.max()), 1))
                next_tok = self._sample(logits, jnp.asarray(temps),
                                        jnp.asarray(topks),
                                        jnp.asarray(seeds),
                                        jnp.asarray(idxs), k_cap=k_cap)
            else:  # all-greedy step (the default): skip the sampler's
                # top-k threshold + Gumbel draw on the hot path
                next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            toks = np.asarray(next_tok)   # the step's ONE device->host sync
        self.cache_len[act] += 1
        self.last_token[act] = toks[act]
        self.obs.on_tokens(len(active))
        now = _clock()
        for i in active:
            req = self.sched.slots[i].req
            req.out.append(int(toks[i]))
            if req.t_first is None:
                req.t_first = now
            self.sched.on_decode_token(i)
            self._maybe_retire(i, req, now, finished)

    # -- speculative decode ---------------------------------------------------
    def _draft_for(self, i: int) -> list:
        """The slot's draft, capped so a full accept can neither overshoot
        ``max_new`` nor run ``cache_len`` past the ``max_seq - 1`` retire
        line.  Drafting is pure host policy over prompt + generated
        history; its failures are *per-request* (caught by the caller)."""
        req = self.sched.slots[i].req
        room = min(self.draft_k,
                   req.max_new - len(req.out) - 1,
                   self.max_seq - 2 - int(self.cache_len[i]))
        if room <= 0:
            return []
        seq = list(req.tokens) + list(req.out)
        return [int(t) for t in self.drafter.draft(seq, room)][:room]

    def _decode_speculative(self, active: list, finished: list) -> None:
        """One verify step: draft on the host, verify all slots' drafts in
        ONE fixed-shape forward (width ``draft_k + 1``), accept each
        slot's longest matching prefix plus the model's bonus/correction
        token, and roll back the rest by bookkeeping (contiguous: junk
        K/V past ``cache_len`` is masked/overwritten; paged: tail pages
        grown for rejected tokens shrink back to the pool)."""
        W = self.draft_k + 1
        drafts: dict[int, list] = {}
        for i in list(active):
            try:
                drafts[i] = self._draft_for(i)
            except Exception as e:   # a poisoned request fails alone
                self._fail_slot(i, f"drafter failed: {type(e).__name__}: {e}")
                active.remove(i)
        if self.paged and active:
            # baseline growth (next token's page) keeps plain-decode
            # semantics: preempt youngest-first, fail a lone un-backable
            # sequence.  Draft pages on top are OPPORTUNISTIC — a draft is
            # never worth preempting a live sequence for, so on exhaustion
            # the draft is dropped and the slot verifies as plain decode.
            active = self._grow_active(active)
            for i in list(active):
                d = drafts.get(i, [])
                if not d:
                    continue
                try:
                    self.alloc.grow(i, int(self.cache_len[i]) + 1 + len(d))
                except PagePoolExhausted:
                    drafts[i] = []
        finished.extend(self._failed)
        self._failed.clear()
        if not active:
            return
        toks = np.zeros((self.n_slots, W), np.int32)
        for i in active:
            toks[i, 0] = self.last_token[i]
            d = drafts.get(i, [])
            if d:
                toks[i, 1:1 + len(d)] = d
        kw = {"page_table": self._page_table()} if self.paged else {}
        with self.obs.phase("verify", slots=len(active),
                            drafted=sum(len(d) for d in drafts.values())):
            logits, self.caches = self._verify(self.params,
                                               jnp.asarray(toks),
                                               self.caches,
                                               jnp.asarray(self.cache_len),
                                               **kw)
            temps, topks, seeds, idxs = self._sampling_operands(active)
            if temps.any():
                k_cap = next_pow2(max(int(topks.max()), 1))
                cand = self._sample_verify(logits, jnp.asarray(temps),
                                           jnp.asarray(topks),
                                           jnp.asarray(seeds),
                                           jnp.asarray(idxs), k_cap=k_cap)
            else:
                cand = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cand = np.asarray(cand)       # (n_slots, W); the ONE host sync
        now = _clock()
        self.spec_steps += 1
        self.obs.on_spec_step()
        for i in active:
            req = self.sched.slots[i].req
            d = drafts.get(i, [])
            # cand[i, j] is the token sequential decode would emit at
            # output index idxs[i]+j given the draft prefix d[:j]; draft
            # token j survives iff it predicted exactly that.  The first
            # mismatch position contributes the model's own token (the
            # bonus/correction), so every step emits 1..W tokens and the
            # stream equals plain decode's token for token.
            n_acc = 1
            for j, dt in enumerate(d):
                if int(cand[i, j]) != dt:
                    break
                n_acc += 1
            emitted = [int(t) for t in cand[i, :n_acc]]
            self.spec_drafted += len(d)
            self.spec_accepted += n_acc - 1
            self.obs.on_draft_verified(req.rid, len(d), n_acc - 1)
            self.obs.on_tokens(n_acc)
            self.sched.on_draft(i, len(d), n_acc - 1)
            self.cache_len[i] += n_acc
            self.last_token[i] = emitted[-1]
            req.out.extend(emitted)
            if req.t_first is None:
                req.t_first = now
            for _ in range(n_acc):
                self.sched.on_decode_token(i)
            if self.paged:
                # rollback: return the pages grown for rejected draft
                # tokens (a draft cut at a page boundary must not leak)
                self.alloc.shrink(i, int(self.cache_len[i]))
            self._maybe_retire(i, req, now, finished)

    # -- admission control ----------------------------------------------------
    def _admissible(self, req: Request) -> bool:
        """Paged admission control: admit only if the sequence's pages are
        free right now (retiring sequences release pages continuously, so
        deferred requests drain from the pending queue).  Raises
        :class:`PagePoolExhausted` for requests no pool state could ever
        admit."""
        if not self.paged:
            return True
        n = len(req.tokens) + len(req.out)
        if n > self.max_seq:
            raise PagePoolExhausted(
                f"request {req.rid} length {n} exceeds max_seq "
                f"{self.max_seq}")
        need = self.pcfg.pages_for(n)
        if need > self.pcfg.n_pages - 1:
            raise PagePoolExhausted(
                f"request {req.rid} needs {need} pages but the pool only "
                f"has {self.pcfg.n_pages - 1} allocatable")
        if self.prefix_cache_active:
            hashes, cap = self._prefix_hashes(req, n)
            return self.alloc.can_admit(n, hits=self.alloc.lookup(hashes[:cap]))
        return self.alloc.can_admit(n)

    # -- the loop -------------------------------------------------------------
    def run(self, requests: list[Request], max_steps: int = 1000):
        """Serve ``requests`` to completion.  Preempted sequences re-enter
        ahead of fresh ones; requests the pool can never back come back with
        ``req.error`` set instead of crashing the loop.  Exhausting
        ``max_steps`` returns *every* request: unfinished ones (still in a
        slot, preempted, or never admitted) come back with ``req.error``
        set, ``done=False`` and whatever ``req.out`` they produced."""
        now = _clock()
        for req in requests:
            if req.t_submit is None:
                req.t_submit = now
            self.sched.enqueue(req)
        done = []
        steps = 0
        while (self.sched.has_queued or self.sched.busy) \
                and steps < max_steps:
            while self.sched.has_queued and self.sched.free_slot() is not None:
                try:
                    if not self._admissible(self.sched.next_queued()):
                        break
                except PagePoolExhausted as e:
                    req = self.sched.pop_queued()
                    req.error, req.done = str(e), True
                    req.t_done = _clock()
                    self.obs.on_retire(req)
                    done.append(req)
                    continue
                self.add_request(self.sched.pop_queued())
            done.extend(self.step())
            steps += 1
        # max_steps exhausted with work still in flight: surface every
        # unfinished request (slot-bound, preempted-unresumed, and
        # never-admitted) with req.error set and partial req.out kept,
        # rather than letting any of them vanish from the return value.
        for slot in self.sched.occupied():
            req = self.sched.release(slot)
            self.cache_len[slot] = 0
            self._slot_seq[slot] = None
            if self.paged:
                self._slot_hashes[slot] = None
                self.alloc.free(slot)
            req.error = req.error or (
                f"evicted mid-flight at max_steps={max_steps}")
            done.append(req)
        for req in self.sched.resume:
            req.error = req.error or (
                f"preempted and not resumed within max_steps={max_steps}")
            done.append(req)
        self.sched.resume = []
        for req in self.sched.pending:
            req.error = req.error or (
                f"never admitted within max_steps={max_steps}")
            done.append(req)
        self.sched.pending = []
        now = _clock()
        for req in done:
            if req.error is not None and req.t_done is None:
                req.t_done = now   # terminal requests carry a completion mark
                self.obs.on_retire(req)
        return done

"""Serving engine: slot-based continuous batching with shape-bucketed
prefill — the runtime-programmability story (paper §IV-C) end to end.

One decode executable (batch = n_slots, the synthesis-time maximum) serves
every request mix; prefill compiles once per sequence-length *bucket*
(pow-2 rounding, right-padded), so arbitrary request lengths reuse a handful
of executables — the TPU analogue of "reprogram loop bounds from the µB,
never re-synthesise".

Bucket-padded prefill correctness: padded suffix tokens write junk K/V at
positions ≥ n−1, but ``cache_len`` masks every future decode step to
positions < len, and the next real token overwrites slot n−1.  (The logits
of the prefill are discarded; generation restarts by decoding the last
prompt token.)  Architectures with recurrent state (RG-LRU / RWKV), where
junk tokens would pollute the carried state, prefill at exact length
instead — the engine picks the strategy from the config.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig
from repro.core.famous import FamousConfig
from repro.core.flexible import next_pow2
from repro.models import transformer


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, fcfg: FamousConfig,
                 n_slots: int = 4, max_seq: int = 256, dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.fcfg = fcfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.dtype = dtype
        self.caches = transformer.make_caches(cfg, n_slots, max_seq, dtype)
        self.cache_len = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.last_token = jnp.zeros((n_slots,), jnp.int32)
        self._prefill_exec: dict[int, callable] = {}
        self._decode = jax.jit(
            functools.partial(transformer.decode_step, cfg=cfg, fcfg=fcfg))
        # recurrent state cannot absorb junk pad tokens -> exact-length prefill
        self.bucketed = all(k in (ATTN, LOCAL_ATTN) for k in cfg.pattern_unit)

    # -- compiled helpers ---------------------------------------------------
    def _prefill_fn(self, length: int):
        if length not in self._prefill_exec:
            def fn(params, tokens, caches, slot):
                one = transformer.make_caches(self.cfg, 1, self.max_seq,
                                              self.dtype)
                _, one = transformer.prefill(params, tokens, one, self.cfg,
                                             self.fcfg)

                def write(axis):
                    def w(buf, new):
                        return jax.lax.dynamic_update_slice_in_dim(
                            buf, new.astype(buf.dtype), slot, axis=axis)
                    return w

                # stacked block caches carry (num_units, batch, ...): the
                # slot/batch axis is 1; tail caches carry (batch, ...).
                out = {"blocks": jax.tree_util.tree_map(
                    write(1), caches["blocks"], one["blocks"])}
                for key in caches:
                    if key != "blocks":
                        out[key] = jax.tree_util.tree_map(
                            write(0), caches[key], one[key])
                return out

            self._prefill_exec[length] = jax.jit(fn)
        return self._prefill_exec[length]

    @property
    def prefill_compilations(self) -> int:
        return len(self._prefill_exec)

    # -- API ------------------------------------------------------------------
    def add_request(self, req: Request) -> int:
        slot = self.slot_req.index(None)
        n = len(req.tokens)
        assert 1 <= n <= self.max_seq
        # prefill the first n-1 tokens; the n-th is decoded (writing its
        # cache entry / recurrent-state update exactly once).
        if n > 1:
            m = n - 1
            plen = min(next_pow2(m), self.max_seq) if self.bucketed else m
            toks = np.zeros((1, plen), np.int32)
            toks[0, :m] = req.tokens[:m]
            fn = self._prefill_fn(plen)
            self.caches = fn(self.params, jnp.asarray(toks), self.caches,
                             jnp.int32(slot))
        else:  # nothing to prefill: clear any stale state in the slot
            cleared = {"blocks": jax.tree_util.tree_map(
                lambda b: b.at[:, slot].set(0), self.caches["blocks"])}
            for key in self.caches:
                if key != "blocks":
                    cleared[key] = jax.tree_util.tree_map(
                        lambda b: b.at[slot].set(0), self.caches[key])
            self.caches = cleared
        self.slot_req[slot] = req
        # generation restarts at the last prompt token: it is re-decoded so
        # its K/V (or recurrent-state) entry is written at position n-1.
        self.cache_len = self.cache_len.at[slot].set(n - 1)
        self.last_token = self.last_token.at[slot].set(req.tokens[-1])
        return slot

    def step(self):
        """One batched decode step across all active slots."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        logits, self.caches = self._decode(self.params, self.last_token,
                                           self.caches, self.cache_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        mask = jnp.asarray([r is not None for r in self.slot_req])
        self.cache_len = self.cache_len + mask.astype(jnp.int32)
        self.last_token = jnp.where(mask, next_tok, self.last_token)
        finished = []
        toks = np.asarray(next_tok)
        for i in active:
            req = self.slot_req[i]
            req.out.append(int(toks[i]))
            if len(req.out) >= req.max_new or int(self.cache_len[i]) >= self.max_seq - 1:
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
                self.cache_len = self.cache_len.at[i].set(0)
        return finished

    def run(self, requests: list[Request], max_steps: int = 1000):
        pending = list(requests)
        done = []
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while pending and None in self.slot_req:
                self.add_request(pending.pop(0))
            done.extend(self.step())
            steps += 1
        return done

"""Serving engine: slot-based continuous batching with shape-bucketed
prefill — the runtime-programmability story (paper §IV-C) end to end.

One decode executable (batch = n_slots, the synthesis-time maximum) serves
every request mix; prefill compiles once per sequence-length *bucket*
(pow-2 rounding, right-padded), so arbitrary request lengths reuse a handful
of executables — the TPU analogue of "reprogram loop bounds from the µB,
never re-synthesise".

Bucket-padded prefill correctness: padded suffix tokens write junk K/V at
positions ≥ n−1, but ``cache_len`` masks every future decode step to
positions < len, and the next real token overwrites slot n−1.  (The logits
of the prefill are discarded; generation restarts by decoding the last
prompt token.)  Architectures with recurrent state (RG-LRU / RWKV), where
junk tokens would pollute the carried state, prefill at exact length
instead — the engine picks the strategy from the config.

KV-cache layout is a config switch (``cache_kind``):

  * ``"contiguous"`` — each slot owns a ``max_seq`` stripe of every
    attention layer's cache (the seed baseline; memory = n_slots × max_seq
    regardless of what is actually resident).
  * ``"paged"``      — global-attention layers share a page pool; slots
    hold pages through a host-side :class:`~repro.serve.paged.PageAllocator`
    and the decode executable receives the page table as a plain int32
    operand each step (same executable for every allocation state).  Memory
    scales with live tokens and admission control degrades cleanly: requests
    the pool cannot back yet wait in the pending queue, sequences that run
    out of pages mid-decode are preempted youngest-first and resumed later
    (token-identically — resuming is just a longer prefill), and impossible
    requests raise :class:`~repro.serve.paged.PagePoolExhausted` (or come
    back with ``req.error`` from :meth:`run`).  docs/serving.md walks
    through the lifecycle.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig
from repro.core.famous import FamousConfig
from repro.core.flexible import next_pow2
from repro.models import transformer
from repro.serve.paged import (PageAllocator, PagedCacheConfig,
                               PagePoolExhausted)


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: Optional[str] = None  # set when the page pool can never back it


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, fcfg: FamousConfig,
                 n_slots: int = 4, max_seq: int = 256, dtype=jnp.float32,
                 cache_kind: str = "contiguous", page_size: int = 16,
                 n_pages: Optional[int] = None):
        assert cache_kind in ("contiguous", "paged"), cache_kind
        self.params = params
        self.cfg = cfg
        self.fcfg = fcfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.dtype = dtype
        self.cache_kind = cache_kind
        self.paged = cache_kind == "paged"
        if self.paged:
            assert max_seq % page_size == 0, (max_seq, page_size)
            if n_pages is None:  # drop-in capacity; pass n_pages to oversubscribe
                n_pages = PagedCacheConfig.default_pool(n_slots, max_seq,
                                                        page_size)
            self.pcfg = PagedCacheConfig(page_size=page_size, n_pages=n_pages)
            self.alloc = PageAllocator(self.pcfg, n_slots, max_seq)
            self.caches = transformer.make_caches(
                cfg, n_slots, max_seq, dtype, cache_kind="paged",
                page_size=page_size, n_pages=n_pages)
        else:
            self.caches = transformer.make_caches(cfg, n_slots, max_seq, dtype)
        self.cache_len = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.last_token = jnp.zeros((n_slots,), jnp.int32)
        # admission order per slot (youngest-first preemption policy) and the
        # queue of preempted requests awaiting re-admission
        self._admit_counter = 0
        self._slot_admit = [-1] * n_slots
        self._preempted: list[Request] = []
        self._failed: list[Request] = []
        self._pt_version = -1          # device page-table cache key
        self._pt_device = None
        self._prefill_exec: dict[int, callable] = {}
        self._decode = jax.jit(
            functools.partial(transformer.decode_step, cfg=cfg, fcfg=fcfg))
        self._clear = jax.jit(functools.partial(
            transformer.clear_slot, cfg=cfg, paged=self.paged))
        # recurrent state cannot absorb junk pad tokens -> exact-length prefill
        self.bucketed = all(k in (ATTN, LOCAL_ATTN) for k in cfg.pattern_unit)

    # -- compiled helpers ---------------------------------------------------
    def _prefill_fn(self, length: int):
        if length not in self._prefill_exec:
            def fn(params, tokens, caches, slot, page_ids):
                one = transformer.make_caches(self.cfg, 1, self.max_seq,
                                              self.dtype)
                _, one = transformer.prefill(params, tokens, one, self.cfg,
                                             self.fcfg)
                return transformer.write_prefill_to_slot(
                    caches, one, slot, self.cfg,
                    page_ids=page_ids if self.paged else None)

            self._prefill_exec[length] = jax.jit(fn)
        return self._prefill_exec[length]

    @property
    def prefill_compilations(self) -> int:
        return len(self._prefill_exec)

    def _page_table(self):
        """Device copy of the page table, re-uploaded only when the
        allocator actually mutated (steady-state decode re-uses it)."""
        if self._pt_version != self.alloc.version:
            self._pt_device = jnp.asarray(self.alloc.page_table)
            self._pt_version = self.alloc.version
        return self._pt_device

    # -- API ------------------------------------------------------------------
    def add_request(self, req: Request) -> int:
        """Admit a request into a free slot.  Paged mode reserves the
        prompt's pages first; on :class:`PagePoolExhausted` the engine state
        is untouched (clean admission control — callers may retry after
        other sequences retire).

        A preempted request (non-empty ``req.out``) resumes here: its full
        prefix (prompt + generated-so-far) is re-prefilled and greedy decode
        continues token-identically from where it stopped.
        """
        slot = self.slot_req.index(None)
        seq = list(req.tokens) + list(req.out)
        n = len(seq)
        assert 1 <= n <= self.max_seq
        if self.paged:
            self.alloc.grow(slot, n)  # raises PagePoolExhausted if oversize
        page_ids = (jnp.asarray(self.alloc.page_table[slot]) if self.paged
                    else jnp.zeros((0,), jnp.int32))
        # prefill the first n-1 tokens; the n-th is decoded (writing its
        # cache entry / recurrent-state update exactly once).
        if n > 1:
            m = n - 1
            plen = min(next_pow2(m), self.max_seq) if self.bucketed else m
            toks = np.zeros((1, plen), np.int32)
            toks[0, :m] = seq[:m]
            fn = self._prefill_fn(plen)
            self.caches = fn(self.params, jnp.asarray(toks), self.caches,
                             jnp.int32(slot), page_ids)
        else:  # nothing to prefill: clear any stale state in the slot
            self.caches = self._clear(self.caches, jnp.int32(slot))
        self.slot_req[slot] = req
        self._slot_admit[slot] = self._admit_counter
        self._admit_counter += 1
        # generation restarts at the last prompt token: it is re-decoded so
        # its K/V (or recurrent-state) entry is written at position n-1.
        self.cache_len = self.cache_len.at[slot].set(n - 1)
        self.last_token = self.last_token.at[slot].set(seq[-1])
        return slot

    def _preempt(self, slot: int) -> None:
        """Evict a running sequence: free its pages and queue it for
        re-admission (its generated tokens stay on the request, so resuming
        is just a longer prefill — no state is copied or swapped out)."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.cache_len = self.cache_len.at[slot].set(0)
        self.alloc.free(slot)
        self._preempted.append(req)

    def _grow_active(self, active: list) -> list:
        """Reserve the next token's page for every active slot, preempting
        youngest-first when the pool is out of pages.  A lone sequence that
        cannot grow is failed (req.error) rather than crashing the engine."""
        lens = np.asarray(self.cache_len)
        for i in list(active):
            if i not in active:
                continue
            while True:
                try:
                    self.alloc.grow(i, int(lens[i]) + 1)
                    break
                except PagePoolExhausted as e:
                    victim = max(active, key=lambda j: self._slot_admit[j])
                    if victim == i and len(active) == 1:
                        # nothing left to preempt: the pool can never back
                        # this sequence — fail it cleanly
                        req = self.slot_req[i]
                        req.error = str(e)
                        req.done = True
                        self.slot_req[i] = None
                        self.cache_len = self.cache_len.at[i].set(0)
                        self.alloc.free(i)
                        active.remove(i)
                        self._failed.append(req)
                        break
                    self._preempt(victim)
                    active.remove(victim)
                    if victim == i:
                        break
        return active

    def step(self):
        """One batched decode step across all active slots.  Returns the
        requests that finished (or, paged mode, failed) this step."""
        finished = []
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if self.paged and active:
            # ensure every active slot has a page for the token it is about
            # to write (position cache_len -> page cache_len // page_size);
            # may preempt or fail sequences when the pool is oversubscribed
            active = self._grow_active(active)
            finished.extend(self._failed)
            self._failed.clear()
        if not active:
            return finished
        if self.paged:
            logits, self.caches = self._decode(
                self.params, self.last_token, self.caches, self.cache_len,
                page_table=self._page_table())
        else:
            logits, self.caches = self._decode(self.params, self.last_token,
                                               self.caches, self.cache_len)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        mask = jnp.asarray([r is not None for r in self.slot_req])
        self.cache_len = self.cache_len + mask.astype(jnp.int32)
        self.last_token = jnp.where(mask, next_tok, self.last_token)
        toks = np.asarray(next_tok)
        for i in active:
            req = self.slot_req[i]
            req.out.append(int(toks[i]))
            if len(req.out) >= req.max_new or int(self.cache_len[i]) >= self.max_seq - 1:
                req.done = True
                finished.append(req)
                self.slot_req[i] = None
                self.cache_len = self.cache_len.at[i].set(0)
                if self.paged:
                    self.alloc.free(i)  # pages return to the pool
        return finished

    def _admissible(self, req: Request) -> bool:
        """Paged admission control: admit only if the sequence's pages are
        free right now (retiring sequences release pages continuously, so
        deferred requests drain from the pending queue).  Raises
        :class:`PagePoolExhausted` for requests no pool state could ever
        admit."""
        if not self.paged:
            return True
        n = len(req.tokens) + len(req.out)
        if n > self.max_seq:
            raise PagePoolExhausted(
                f"request {req.rid} length {n} exceeds max_seq "
                f"{self.max_seq}")
        need = self.pcfg.pages_for(n)
        if need > self.pcfg.n_pages - 1:
            raise PagePoolExhausted(
                f"request {req.rid} needs {need} pages but the pool only "
                f"has {self.pcfg.n_pages - 1} allocatable")
        return self.alloc.can_admit(n)

    def run(self, requests: list[Request], max_steps: int = 1000):
        """Serve ``requests`` to completion.  Preempted sequences re-enter
        ahead of fresh ones; requests the pool can never back come back with
        ``req.error`` set instead of crashing the loop."""
        pending = list(requests)
        done = []
        steps = 0
        while (pending or self._preempted
               or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while (self._preempted or pending) and None in self.slot_req:
                queue = self._preempted if self._preempted else pending
                try:
                    if not self._admissible(queue[0]):
                        break
                except PagePoolExhausted as e:
                    req = queue.pop(0)
                    req.error, req.done = str(e), True
                    done.append(req)
                    continue
                self.add_request(queue.pop(0))
            done.extend(self.step())
            steps += 1
        # max_steps exhausted with work still queued: surface evicted
        # requests rather than letting them vanish (partial req.out kept)
        for req in self._preempted:
            req.error = req.error or (
                f"preempted and not resumed within max_steps={max_steps}")
            done.append(req)
        self._preempted = []
        return done

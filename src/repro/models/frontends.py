"""Modality frontend STUBS (per the assignment, ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs document what the real frontend would be and generate deterministic
synthetic embeddings of the right shape for smoke tests and benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

FRONTEND_DOC = {
    "audio": "HuBERT CNN waveform encoder: 7-layer conv stack, 20 ms stride "
             "-> frame embeddings (B, S, d_model).",
    "vlm": "LLaVA-NeXT anyres tiler + CLIP ViT + 2-layer MLP projector -> "
           "patch embeddings interleaved with text embeddings (B, S, d_model).",
}


def embed_shape(cfg: ModelConfig, batch: int, seq: int):
    return (batch, seq, cfg.d_model)


def input_struct(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-in for the frontend output (dry-run)."""
    return jax.ShapeDtypeStruct(embed_shape(cfg, batch, seq), dtype)


def synthetic_embeddings(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                         dtype=jnp.float32):
    """Deterministic fake frontend output for smoke tests/benchmarks."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, embed_shape(cfg, batch, seq), jnp.float32)
    return (0.02 * x).astype(dtype)

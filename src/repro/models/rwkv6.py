"""RWKV-6 "Finch" block: data-dependent-decay linear attention + channel mix.

wkv6 recurrence per head (state S ∈ R^{dk×dv}):

    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ,       w_t = exp(-exp(ŵ_t)) ∈ (0,1)

Training/prefill uses the *chunked* parallel form (flash-linear-attention
style): intra-chunk via masked matmuls with cumulative log-decays (all decay
ratios ≤ 1 → numerically safe), inter-chunk state carried by a lax.scan.
The Pallas kernel in kernels/scan implements the same algorithm; this is its
oracle and the XLA path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.module import ParamSpec

_LORA = 64  # low-rank width of the data-dependent decay projection


def rwkv6_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    lora = min(_LORA, d)
    return {
        # token-shift mixing coefficients (r, k, v, w, g)
        "mu": ParamSpec((5, d), (None, "embed"), init="uniform", scale=0.5),
        "w_r": ParamSpec((d, d), ("embed", "heads_flat")),
        "w_k": ParamSpec((d, d), ("embed", "heads_flat")),
        "w_v": ParamSpec((d, d), ("embed", "heads_flat")),
        "w_g": ParamSpec((d, d), ("embed", "heads_flat")),
        # decay: ŵ_t = w0 + tanh(x̄ A) B   (low-rank data dependence)
        "w0": ParamSpec((d,), ("heads_flat",), init="uniform", scale=1.0),
        "wA": ParamSpec((d, lora), ("embed", None), scale=0.1),
        "wB": ParamSpec((lora, d), (None, "heads_flat"), scale=0.1),
        "u": ParamSpec((h, dh), ("heads", "head_dim"), init="uniform", scale=0.5),
        "ln_scale": ParamSpec((d,), ("heads_flat",), init="ones"),
        "ln_bias": ParamSpec((d,), ("heads_flat",), init="zeros"),
        "w_o": ParamSpec((d, d), ("heads_flat", "embed")),
    }


def channel_mix_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": ParamSpec((2, d), (None, "embed"), init="uniform", scale=0.5),
        "w_k": ParamSpec((d, f), ("embed", "mlp")),
        "w_v": ParamSpec((f, d), ("mlp", "embed")),
        "w_r": ParamSpec((d, d), ("embed", None)),
    }


# ---------------------------------------------------------------------------
# wkv6 core — chunked parallel form
# ---------------------------------------------------------------------------

def wkv6_chunked(r, k, v, logw, u, s0=None, chunk: int = 64):
    """r,k,v,logw: (B, H, S, dh); logw ≤ 0. u: (H, dh).
    Returns (out (B,H,S,dh) f32, s_final (B,H,dh,dh) f32)."""
    B, H, S, dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    f32 = jnp.float32
    r, k, v, logw = (x.astype(f32) for x in (r, k, v, logw))
    rc = r.reshape(B, H, n, chunk, dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, n, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, n, chunk, dh).transpose(2, 0, 1, 3, 4)
    wc = logw.reshape(B, H, n, chunk, dh).transpose(2, 0, 1, 3, 4)
    if s0 is None:
        s0 = jnp.zeros((B, H, dh, dh), f32)

    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)  # strict lower

    def step(s, blk):
        rb, kb, vb, wb = blk                      # (B,H,C,dh)
        cw = jnp.cumsum(wb, axis=2)               # logW_t   (inclusive)
        cw_prev = cw - wb                          # logW_{t-1}
        # inter-chunk: r_t ⊙ W_{t-1} applied to incoming state
        r_dec = rb * jnp.exp(cw_prev)
        inter = jnp.einsum("bhtd,bhde->bhte", r_dec, s)
        # intra-chunk: A[t,s] = Σ_d r[t,d]·exp(cw_prev[t,d]-cw[s,d])·k[s,d], s<t
        # (decay from s+1..t-1 inclusive = cw_prev[t] - cw[s])
        qexp = rb * jnp.exp(cw_prev)               # fold exp(cw_prev) into r
        kexp = kb * jnp.exp(-cw)                   # fold exp(-cw) into k
        att = jnp.einsum("bhtd,bhsd->bhts", qexp, kexp) * tri
        diag = jnp.einsum("bhtd,bhtd->bht", rb * u[None, :, None, :], kb)
        intra = jnp.einsum("bhts,bhse->bhte", att, vb) + diag[..., None] * vb
        # state update: S' = diag(W_C) S + Σ_t diag(W_C/W_t) k_t v_tᵀ
        wC = jnp.exp(cw[:, :, -1])                 # (B,H,dh)
        k_dec = kb * jnp.exp(cw[:, :, -1:, :] - cw)
        s_new = wC[..., None] * s + jnp.einsum("bhtd,bhte->bhde", k_dec, vb)
        return s_new, inter + intra

    s_final, outs = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return out, s_final


def wkv6_step(r, k, v, logw, u, s):
    """One decode step. r,k,v,logw: (B, H, dh); s: (B, H, dh, dh)."""
    f32 = jnp.float32
    r, k, v, logw = (x.astype(f32) for x in (r, k, v, logw))
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    out = jnp.einsum("bhd,bhde->bhe", r, s + u[None, :, :, None] * kv)
    s_new = jnp.exp(logw)[..., None] * s + kv
    return out, s_new


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _token_shift(x, prev):
    """x: (B, S, D); prev: (B, D) last token of the previous segment."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def make_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    return {
        "s": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dtype),   # time-mix token shift
        "x_cm": jnp.zeros((batch, d), dtype),   # channel-mix token shift
    }


def rwkv_cache_shape(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    return {
        "s": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "x_tm": jax.ShapeDtypeStruct((batch, d), dtype),
        "x_cm": jax.ShapeDtypeStruct((batch, d), dtype),
    }


RWKV_CACHE_AXES = {"s": ("batch", "heads", None, None),
                   "x_tm": ("batch", "embed"), "x_cm": ("batch", "embed")}


def _time_mix_qkvwg(p, x, x_prev):
    d = x.shape[-1]
    xs = [_mix(x, x_prev, p["mu"][i]) for i in range(5)]
    dt = x.dtype
    r = jnp.einsum("bsd,df->bsf", xs[0], p["w_r"].astype(dt))
    k = jnp.einsum("bsd,df->bsf", xs[1], p["w_k"].astype(dt))
    v = jnp.einsum("bsd,df->bsf", xs[2], p["w_v"].astype(dt))
    g = jnp.einsum("bsd,df->bsf", xs[4], p["w_g"].astype(dt))
    wx = _mix(x, x_prev, p["mu"][3]).astype(jnp.float32)
    what = (p["w0"].astype(jnp.float32)
            + jnp.tanh(wx @ p["wA"].astype(jnp.float32))
            @ p["wB"].astype(jnp.float32))
    # Clamp ŵ ≤ 0 so per-step log-decay ∈ [-1, 0): keeps the chunked form's
    # exp(-cumsum) factors within f32 range (|cw| ≤ chunk).  Documented
    # deviation: decays faster than 1/e per token are saturated.
    logw = -jnp.exp(jnp.clip(what, -20.0, 0.0))
    return r, k, v, g, logw


def _heads(x, h, dh):
    return x.reshape(x.shape[0], x.shape[1], h, dh).transpose(0, 2, 1, 3)


def apply_rwkv_time_mix(p, x, cfg: ModelConfig, cache=None, chunk: int = 64):
    B, S, D = x.shape
    h, dh = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    x_prev = _token_shift(x, None if cache is None else cache["x_tm"])
    r, k, v, g, logw = _time_mix_qkvwg(p, x, x_prev)
    rh, kh, vh = (_heads(t, h, dh) for t in (r, k, v))
    wh = _heads(logw, h, dh)
    s0 = None if cache is None else cache["s"]
    out, s_fin = wkv6_chunked(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                              s0=s0, chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, D)
    # per-head group norm then gate
    out = layers.apply_norm({"scale": p["ln_scale"], "bias": p["ln_bias"]},
                            out.astype(x.dtype), "layernorm")
    out = out * jax.nn.silu(g)
    y = jnp.einsum("bsd,df->bsf", out, p["w_o"].astype(x.dtype))
    if cache is None:
        return y
    return y, {"s": s_fin, "x_tm": x[:, -1]}


def apply_rwkv_time_mix_chunk(p, x, cache, cfg: ModelConfig, n_valid,
                              chunk: int = 64):
    """Chunked prefill: carry (s, x_tm) across fixed-shape chunks.

    x: (1, C, D) — only the first n_valid positions are real.  Pad
    positions are masked so they cannot pollute the carried state: their
    k is zeroed (no kv outer-product contribution) and their log-decay is
    zeroed (w = 1, identity decay), so ``s_final`` is exactly the state
    after the last real token; the token-shift state becomes
    ``x[:, n_valid-1]``.  Pad *outputs* are junk and discarded upstream.
    """
    B, S, D = x.shape
    h, dh = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    x_prev = _token_shift(x, cache["x_tm"])
    r, k, v, g, logw = _time_mix_qkvwg(p, x, x_prev)
    valid = (jnp.arange(S) < n_valid)[None, :, None]
    k = jnp.where(valid, k, jnp.zeros((), k.dtype))
    logw = jnp.where(valid, logw, 0.0)
    rh, kh, vh = (_heads(t, h, dh) for t in (r, k, v))
    wh = _heads(logw, h, dh)
    out, s_fin = wkv6_chunked(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                              s0=cache["s"], chunk=chunk)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, D)
    out = layers.apply_norm({"scale": p["ln_scale"], "bias": p["ln_bias"]},
                            out.astype(x.dtype), "layernorm")
    out = out * jax.nn.silu(g)
    y = jnp.einsum("bsd,df->bsf", out, p["w_o"].astype(x.dtype))
    x_last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)[:, 0]
    return y, {"s": s_fin, "x_tm": x_last}


def apply_rwkv_time_mix_decode(p, x, cache, cfg: ModelConfig):
    B, _, D = x.shape
    h, dh = D // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    x_prev = cache["x_tm"][:, None]
    r, k, v, g, logw = _time_mix_qkvwg(p, x, x_prev)
    rh = r.reshape(B, h, dh)
    kh = k.reshape(B, h, dh)
    vh = v.reshape(B, h, dh)
    wh = logw.reshape(B, h, dh)
    out, s_new = wkv6_step(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                           cache["s"])
    out = out.reshape(B, 1, D)
    out = layers.apply_norm({"scale": p["ln_scale"], "bias": p["ln_bias"]},
                            out.astype(x.dtype), "layernorm")
    out = out * jax.nn.silu(g)
    y = jnp.einsum("bsd,df->bsf", out, p["w_o"].astype(x.dtype))
    return y, {"s": s_new, "x_tm": x[:, -1]}


def apply_channel_mix(p, x, cfg: ModelConfig, cache_x=None):
    """RWKV channel mix (relu² FFN with token shift). Returns (y, x_last)."""
    x_prev = _token_shift(x, cache_x)
    xk = _mix(x, x_prev, p["mu"][0])
    xr = _mix(x, x_prev, p["mu"][1])
    dt = x.dtype
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"].astype(dt))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"].astype(dt)))
    return rr * vv, x[:, -1]

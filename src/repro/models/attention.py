"""Attention block: GQA dense MHA built on the FAMOUS core, with KV caching
(full or sliding-window ring buffer) for serving."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import famous
from repro.core import quant as quant_lib
from repro.models import layers
from repro.models.module import ParamSpec
from repro.parallel.incontext import constrain_attn_activations


def attn_spec(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.attention_bias:
        spec["bq"] = ParamSpec((h, dh), ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = ParamSpec((dh,), (None,), init="ones")
        spec["k_norm"] = ParamSpec((dh,), (None,), init="ones")
    return spec


def make_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int,
                    dtype) -> dict:
    """KV cache. Sliding-window layers use an O(window) ring buffer."""
    slots = min(max_seq, window) if window else max_seq
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, slots, kv, dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attn_cache_shape(cfg: ModelConfig, batch: int, max_seq: int, window: int,
                     dtype) -> dict:
    slots = min(max_seq, window) if window else max_seq
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    sds = jax.ShapeDtypeStruct((batch, slots, kv, dh), dtype)
    return {"k": sds, "v": sds}


ATTN_CACHE_AXES = {"k": ("batch", None, "kv_heads", "head_dim"),
                   "v": ("batch", None, "kv_heads", "head_dim")}

# Paged pools have no slot axis — (n_pages, page_size, kv, dh) — so tensor
# parallelism shards the kv-head dim; page ids/tables are head-agnostic and
# the host-side allocator stays single-copy.  int8 pools carry fp32 scale
# pools (n_pages, page_size, kv) that shard the same way.
PAGED_ATTN_CACHE_AXES = {"k": (None, None, "kv_heads", "head_dim"),
                         "v": (None, None, "kv_heads", "head_dim")}
PAGED_ATTN_CACHE_AXES_INT8 = {**PAGED_ATTN_CACHE_AXES,
                              "k_scale": (None, None, "kv_heads"),
                              "v_scale": (None, None, "kv_heads")}


def make_paged_attn_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                          dtype, kv_dtype: str = "fp") -> dict:
    """Shared page pool for a global-attention layer: every sequence's K/V
    live in fixed-size pages addressed through a per-slot page table (no
    per-slot batch axis here — the pool is the batch).

    ``kv_dtype="int8"`` stores the pools as int8 with parallel fp32
    ``k_scale``/``v_scale`` pools of shape (n_pages, page_size, kv) — one
    symmetric scale per (token, kv head), written in the same scatter as
    the page row so scale rows share the page's id/lifetime by
    construction (alloc/free/shrink/COW all stay in lockstep for free).
    """
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    shape = (n_pages, page_size, kv, dh)
    if kv_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    assert kv_dtype == "fp", kv_dtype
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_attn_cache_shape(cfg: ModelConfig, n_pages: int, page_size: int,
                           dtype, kv_dtype: str = "fp") -> dict:
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    if kv_dtype == "int8":
        sds = jax.ShapeDtypeStruct((n_pages, page_size, kv, dh), jnp.int8)
        ssd = jax.ShapeDtypeStruct((n_pages, page_size, kv), jnp.float32)
        return {"k": sds, "v": sds, "k_scale": ssd, "v_scale": ssd}
    assert kv_dtype == "fp", kv_dtype
    sds = jax.ShapeDtypeStruct((n_pages, page_size, kv, dh), dtype)
    return {"k": sds, "v": sds}


def _kv_quantize(x):
    """Per-(token, kv-head) symmetric int8 over head_dim: x (..., kv, dh)
    -> (int8 (..., kv, dh), fp32 scale (..., kv))."""
    q, s = quant_lib.quantize(x, axis=-1)
    return q, s[..., 0].astype(jnp.float32)


def _paged_write(cache: dict, pids, offs, k, v) -> dict:
    """Scatter per-token K/V rows into the page pool at (pids, offs) —
    quantizing at write time when the pool is int8 (``k_scale`` present)."""
    if "k_scale" in cache:
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        return {"k": cache["k"].at[pids, offs].set(kq),
                "v": cache["v"].at[pids, offs].set(vq),
                "k_scale": cache["k_scale"].at[pids, offs].set(ks),
                "v_scale": cache["v_scale"].at[pids, offs].set(vs)}
    return {"k": cache["k"].at[pids, offs].set(k.astype(cache["k"].dtype)),
            "v": cache["v"].at[pids, offs].set(v.astype(cache["v"].dtype))}


def _pool_scales(cache: dict) -> dict:
    """kwargs routing famous.* paged attention onto the int8 kernels."""
    if "k_scale" in cache:
        return {"k_scale": cache["k_scale"], "v_scale": cache["v_scale"]}
    return {}


def _project(p, x, cfg: ModelConfig, fcfg: famous.FamousConfig, positions):
    q, k, v = famous.qkv_projection(
        x, p["wq"], p["wk"], p["wv"], p.get("bq"), p.get("bk"), p.get("bv"),
        cfg=fcfg)
    if cfg.qk_norm:
        q = layers.rms_head_norm(q, p["q_norm"])
        k = layers.rms_head_norm(k, p["k_norm"])
    if cfg.rope:
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    return constrain_attn_activations(q, k, v, cfg.num_heads)


def apply_attn(p: dict, x: jax.Array, cfg: ModelConfig,
               fcfg: famous.FamousConfig, *, window: int = 0,
               q_offset: int = 0) -> jax.Array:
    """Full-sequence attention (training / encoder / prefill compute)."""
    S = x.shape[1]
    positions = q_offset + jnp.arange(S)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    out = famous.attention(q, k, v, causal=cfg.causal, window=window,
                           q_offset=q_offset, cfg=fcfg)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))


def apply_attn_prefill(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
                       fcfg: famous.FamousConfig, *, window: int = 0):
    """Prefill: full attention + populate the KV cache. Returns (out, cache)."""
    S = x.shape[1]
    positions = jnp.arange(S)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    out = famous.attention(q, k, v, causal=cfg.causal, window=window, cfg=fcfg)
    slots = cache["k"].shape[1]
    if slots >= S:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1),
        }
    else:  # ring buffer keeps the last `slots` positions at pos % slots
        tail_k, tail_v = k[:, S - slots:], v[:, S - slots:]
        shift = S % slots  # slot of the oldest kept position
        idx = (jnp.arange(slots) + shift) % slots
        inv = jnp.argsort(idx)
        cache = {"k": tail_k[:, inv], "v": tail_v[:, inv]}
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_chunk(p: dict, x: jax.Array, cache: dict, slot, offset,
                     n_valid, cfg: ModelConfig, fcfg: famous.FamousConfig, *,
                     window: int = 0):
    """Chunked prefill for one slot of the *batched* cache.

    x: (1, C, D) — the chunk at absolute positions [offset, offset+C)
    (``offset`` a runtime scalar); cache: {"k","v"} (n_slots, S|ring, kv,
    dh).  Writes the chunk's K/V straight into the slot (no batch-1
    round-trip) and attends against resident prefix + own chunk.  Pad
    positions at the chunk tail write junk K/V beyond the prompt, which is
    never read: causal masking excludes them during prefill and decode
    overwrites position n-1 onwards.  Returns (out (1, C, D), new cache).
    """
    C = x.shape[1]
    positions = offset + jnp.arange(C)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    if not window:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (slot, offset, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (slot, offset, 0, 0))
        k_slot = jax.lax.dynamic_slice(ck, (slot, 0, 0, 0),
                                       (1,) + ck.shape[1:])
        v_slot = jax.lax.dynamic_slice(cv, (slot, 0, 0, 0),
                                       (1,) + cv.shape[1:])
        out = famous.chunked_prefill_attention(q, k_slot, v_slot, offset,
                                               cfg=fcfg)
        cache = {"k": ck, "v": cv}
    else:
        # Ring buffer: gather the pre-chunk ring in *position order*
        # (positions offset-ring .. offset-1; ring slot = pos % ring;
        # negative / not-yet-written positions are masked by
        # attention_at_positions), attend over [gathered ring ‖ chunk],
        # then write the chunk's last min(C, ring) positions into the ring.
        ring = cache["k"].shape[1]
        kv, dh = cache["k"].shape[2], cache["k"].shape[3]
        row_k = jax.lax.dynamic_slice(cache["k"], (slot, 0, 0, 0),
                                      (1, ring, kv, dh))[0]
        row_v = jax.lax.dynamic_slice(cache["v"], (slot, 0, 0, 0),
                                      (1, ring, kv, dh))[0]
        prev_pos = offset - ring + jnp.arange(ring)
        order = prev_pos % ring
        keys = jnp.concatenate([row_k[order][None].astype(k.dtype), k], axis=1)
        vals = jnp.concatenate([row_v[order][None].astype(v.dtype), v], axis=1)
        k_pos = jnp.concatenate([prev_pos, positions])
        out = famous.attention_at_positions(q, keys, vals, positions, k_pos,
                                            window=window)
        # Write only the last min(n_valid, ring) *real* chunk positions —
        # pad-tail junk must not clobber live window slots, and positions
        # older than the final ring window would alias newer ones.  Masked
        # writes are redirected to an out-of-bounds index and dropped; the
        # surviving indices are distinct, so scatter order is irrelevant.
        c_arr = jnp.arange(C)
        write_ok = (c_arr < n_valid) & (c_arr >= n_valid - ring)
        idx = jnp.where(write_ok, positions % ring, ring)
        row_k = row_k.at[idx].set(k[0].astype(row_k.dtype), mode="drop")
        row_v = row_v.at[idx].set(v[0].astype(row_v.dtype), mode="drop")
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], row_k[None],
                                              (slot, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], row_v[None],
                                              (slot, 0, 0, 0)),
        }
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_chunk_paged(p: dict, x: jax.Array, cache: dict, page_table,
                           slot, offset, cfg: ModelConfig,
                           fcfg: famous.FamousConfig):
    """Chunked prefill against the shared page pool.

    x: (1, C, D); cache: {"k","v"} pools (n_pages, page_size, kv, dh);
    page_table: (n_slots, n_p) int32.  The chunk's K/V scatter into the
    slot's pages (position p -> page ``page_table[slot, p // ps]``, offset
    ``p % ps``); pad positions past the reserved pages land on the null
    page, which absorbs them by convention.  Returns (out, new cache).
    """
    C = x.shape[1]
    positions = offset + jnp.arange(C)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    ps = cache["k"].shape[1]
    pt_row = page_table[slot]                          # (n_p,)
    pids = pt_row[positions // ps]
    offs = positions % ps
    cache = _paged_write(cache, pids, offs, k[0], v[0])
    out = famous.paged_chunked_prefill_attention(q, cache["k"], cache["v"],
                                                 pt_row[None], offset,
                                                 cfg=fcfg,
                                                 **_pool_scales(cache))
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_decode(p: dict, x: jax.Array, cache: dict, cache_len,
                      cfg: ModelConfig, fcfg: famous.FamousConfig, *,
                      window: int = 0):
    """One-token decode. x: (B, 1, D); cache_len: (B,) valid entries BEFORE
    this token. Returns (out, new_cache)."""
    B = x.shape[0]
    positions = cache_len[:, None]  # (B, 1) absolute positions
    q, k, v = _project(p, x, cfg, fcfg, positions)
    slots = cache["k"].shape[1]
    slot = (cache_len % slots) if window else cache_len

    def write(buf, new):
        return jax.vmap(
            lambda b, n, s: jax.lax.dynamic_update_slice_in_dim(b, n, s, axis=0)
        )(buf, new, slot)

    cache = {"k": write(cache["k"], k), "v": write(cache["v"], v)}
    valid = jnp.minimum(cache_len + 1, slots) if window else cache_len + 1
    out = famous.decode_attention(q, cache["k"], cache["v"], valid, cfg=fcfg)
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_verify(p: dict, x: jax.Array, cache: dict, cache_len,
                      cfg: ModelConfig, fcfg: famous.FamousConfig):
    """Speculative verify: W tokens per slot in one forward.  x: (B, W, D)
    at absolute positions ``cache_len[b] + j``; cache_len: (B,) valid
    entries BEFORE the first verify token.  Returns (out (B, W, D), cache).

    The W tokens' K/V scatter to their per-slot positions; positions past
    ``max_seq`` (pad rows of slots near capacity) are dropped, not
    clamped — a clamped write would corrupt live entries.  Rejected draft
    positions need no rollback: their K/V stay as junk past the accepted
    ``cache_len``, masked by every later causal read and overwritten by
    the next verify/decode writes before they ever become visible.
    """
    B, W = x.shape[:2]
    positions = cache_len[:, None] + jnp.arange(W)      # (B, W)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    b_idx = jnp.arange(B)[:, None]
    cache = {
        "k": cache["k"].at[b_idx, positions].set(
            k.astype(cache["k"].dtype), mode="drop"),
        "v": cache["v"].at[b_idx, positions].set(
            v.astype(cache["v"].dtype), mode="drop"),
    }
    out = famous.verify_attention(q, cache["k"], cache["v"], cache_len,
                                  cfg=fcfg)
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_verify_paged(p: dict, x: jax.Array, cache: dict, page_table,
                            cache_len, cfg: ModelConfig,
                            fcfg: famous.FamousConfig):
    """Speculative verify against the shared page pool.  x: (B, W, D);
    cache: {"k","v"} pools (n_pages, page_size, kv, dh); page_table:
    (B, n_p) int32; cache_len: (B,) valid entries BEFORE the first verify
    token.  Position p of slot b scatters into page
    ``page_table[b, p // ps]`` — explicitly redirected to the null page
    when ``p // ps`` runs past the table (pad rows of a nearly-full slot;
    a clamped gather would alias a live page and corrupt it).  Rollback of
    rejected tokens is the allocator's job (``PageAllocator.shrink``
    returns the tail pages grown for them); the junk K/V they leave in the
    kept pages is masked/overwritten exactly as in the contiguous case.
    """
    B, W = x.shape[:2]
    positions = cache_len[:, None] + jnp.arange(W)      # (B, W)
    q, k, v = _project(p, x, cfg, fcfg, positions)
    ps = cache["k"].shape[1]
    n_p = page_table.shape[1]
    blk = positions // ps
    b_idx = jnp.arange(B)[:, None]
    pids = jnp.where(blk < n_p,
                     page_table[b_idx, jnp.minimum(blk, n_p - 1)], 0)
    offs = positions % ps
    cache = _paged_write(cache, pids, offs, k, v)
    out = famous.paged_verify_attention(q, cache["k"], cache["v"],
                                        page_table, cache_len, cfg=fcfg,
                                        **_pool_scales(cache))
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache


def apply_attn_decode_paged(p: dict, x: jax.Array, cache: dict, page_table,
                            cache_len, cfg: ModelConfig,
                            fcfg: famous.FamousConfig):
    """One-token decode against the shared page pool.

    x: (B, 1, D); cache: {"k","v"} pools (n_pages, page_size, kv, dh);
    page_table: (B, pages_per_slot) int32; cache_len: (B,) valid entries
    BEFORE this token.  The new token's K/V scatter into page
    ``page_table[b, len // page_size]`` at offset ``len % page_size``.
    Slots may *alias* read-only prefix pages (prefix cache), but every
    write position lies past the slot's shared prefix in a private page,
    so the batched scatter never collides on a non-null page; inactive
    slots write the null page.  Returns (out, cache).
    """
    B = x.shape[0]
    positions = cache_len[:, None]
    q, k, v = _project(p, x, cfg, fcfg, positions)
    ps = cache["k"].shape[1]
    pids = page_table[jnp.arange(B), cache_len // ps]      # (B,)
    offs = cache_len % ps
    cache = _paged_write(cache, pids, offs, k[:, 0], v[:, 0])
    out = famous.paged_decode_attention(q, cache["k"], cache["v"],
                                        page_table, cache_len + 1, cfg=fcfg,
                                        **_pool_scales(cache))
    o = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(out.dtype))
    return o, cache

"""Lightweight functional parameter-definition system.

Models are pure functions over pytrees of jnp arrays.  Parameter trees are
*declared* as pytrees of :class:`ParamSpec` (shape + logical axis names +
initializer), then materialised with :func:`init_params`.  The parallel
machinery consumes the logical-axes tree (same structure) to build
``NamedSharding``s, and the dry-run consumes the shape tree to build
``jax.ShapeDtypeStruct`` stand-ins without allocating anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter tensor.

    Attributes:
      shape:  tensor shape.
      axes:   logical axis name per dim (None = replicated/unsharded dim).
      init:   "zeros" | "ones" | "normal" | "fan_in" | "embed" | "uniform".
      scale:  multiplier applied to the random initializer.
      dtype:  parameter dtype; None -> use the model-wide default.
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"
    scale: float = 1.0
    dtype: Any = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaves_with_path(tree: PyTree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def is_spec_tree_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def _materialise(spec: ParamSpec, key: jax.Array, default_dtype) -> jax.Array:
    dtype = spec.dtype or default_dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "fan_in":
        fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if spec.init == "uniform":
        return (
            spec.scale * jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0)
        ).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree: PyTree, key: jax.Array, default_dtype=jnp.float32) -> PyTree:
    """Materialise a tree of ParamSpec into actual arrays."""
    leaves, treedef = _leaves_with_path(spec_tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [_materialise(spec, k, default_dtype) for (_, spec), k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def param_shapes(spec_tree: PyTree, default_dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        spec_tree,
        is_leaf=is_spec_tree_leaf,
    )


def logical_axes(spec_tree: PyTree) -> PyTree:
    """Tree of logical-axis tuples, same structure as the param tree."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, spec_tree, is_leaf=is_spec_tree_leaf
    )


def stack_specs(spec_tree: PyTree, n: int, stack_axis_name: str | None = "layers") -> PyTree:
    """Prepend a stacking dim of size ``n`` to every spec (for lax.scan layers)."""

    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec(
            shape=(n,) + s.shape,
            axes=(stack_axis_name,) + s.axes,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        )

    return jax.tree_util.tree_map(_stack, spec_tree, is_leaf=is_spec_tree_leaf)


def count_params(spec_tree: PyTree) -> int:
    leaves, _ = _leaves_with_path(spec_tree)
    return sum(int(np.prod(s.shape)) for _, s in leaves)

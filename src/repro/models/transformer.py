"""Generic block-stack model covering all assigned architectures.

The layer stack is ``pattern_unit`` repeated ``num_units`` times via
``jax.lax.scan`` over stacked parameters (keeps the HLO size O(unit), not
O(layers) — essential for the 64-layer/1T-param dry-runs), plus an explicit
tail for patterns that do not divide the layer count (recurrentgemma's 26 = 8
× (R,R,A) + (R,R)).

Three entry points:
  * ``forward``        — full-sequence logits (training / encoder).
  * ``prefill``        — forward + build per-layer caches (serving).
  * ``decode_step``    — one token against the caches (serving decode;
    contiguous per-slot caches, or paged pools when given a page table).

Plus slot-granular cache surgery for the serving engine
(``write_prefill_to_slot`` / ``clear_slot``), which keeps knowledge of the
cache tree's structure out of serve/engine.py.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV6, ModelConfig
from repro.core.famous import FamousConfig
from repro.models import attention, layers, moe, rglru, rwkv6
from repro.models.module import ParamSpec, stack_specs
from repro.parallel.incontext import constrain_residual

# ---------------------------------------------------------------------------
# parameter spec
# ---------------------------------------------------------------------------


def _ffn_spec(cfg: ModelConfig):
    if cfg.num_experts:
        return moe.moe_spec(cfg)
    gated = cfg.act in ("silu", "gelu") and cfg.norm == "rmsnorm"
    return layers.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, gated=gated)


def block_spec(kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if kind in (ATTN, LOCAL_ATTN):
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "attn": attention.attn_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "ffn": _ffn_spec(cfg),
        }
    if kind == RGLRU:
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "rec": rglru.rglru_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "ffn": _ffn_spec(cfg),
        }
    if kind == RWKV6:
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "tm": rwkv6.rwkv6_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "cm": rwkv6.channel_mix_spec(cfg),
        }
    raise ValueError(kind)


def model_spec(cfg: ModelConfig) -> dict:
    unit = {f"pos{i}": block_spec(k, cfg) for i, k in enumerate(cfg.pattern_unit)}
    spec: dict[str, Any] = {
        "embed": layers.embed_spec(cfg.vocab_size, cfg.d_model),
        "blocks": stack_specs(unit, cfg.num_units),
        "final_norm": layers.norm_spec(cfg.d_model, cfg.norm),
    }
    for i, k in enumerate(cfg.tail_layers):
        spec[f"tail{i}"] = block_spec(k, cfg)
    if not cfg.tie_embeddings:
        spec["lm_head"] = {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                           scale=0.02)
        }
    return spec


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------


def _apply_ffn(p, x, cfg: ModelConfig):
    if cfg.num_experts:
        return moe.apply_moe(p, x, cfg)
    return layers.apply_mlp(p, x, cfg.act)


def apply_block(kind: str, p: dict, x: jax.Array, cfg: ModelConfig,
                fcfg: FamousConfig, q_offset: int = 0) -> jax.Array:
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        x = constrain_residual(x, cfg.num_heads)
        x = x + attention.apply_attn(p["attn"], n(p["ln1"], x), cfg, fcfg,
                                     window=window, q_offset=q_offset)
        x = constrain_residual(x, cfg.num_heads)
        h = constrain_residual(n(p["ln2"], x), cfg.num_heads)
        return x + constrain_residual(_apply_ffn(p["ffn"], h, cfg),
                                      cfg.num_heads)
    if kind == RGLRU:
        x = x + rglru.apply_rglru(p["rec"], n(p["ln1"], x), cfg)
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg)
    if kind == RWKV6:
        x = x + rwkv6.apply_rwkv_time_mix(p["tm"], n(p["ln1"], x), cfg)
        y, _ = rwkv6.apply_channel_mix(p["cm"], n(p["ln2"], x), cfg)
        return x + y
    raise ValueError(kind)


def _embed_inputs(params, inputs, cfg: ModelConfig, dtype):
    if jnp.issubdtype(inputs.dtype, jnp.integer):
        return layers.embed_lookup(params["embed"], inputs, dtype)
    return inputs.astype(dtype)  # frontend stub: precomputed embeddings


def _remat_policy(cfg: ModelConfig):
    """§Perf iteration K3 (REFUTED, kept for the record): saving the MoE
    expert-FFN intermediates under save_only_these_names did not remove the
    backward's expert-weight all-gathers (XLA re-gathers for dbuf/dW anyway)
    and cost +36 GiB/device of saved activations — policy disabled."""
    return None


def forward(params: dict, inputs: jax.Array, cfg: ModelConfig,
            fcfg: FamousConfig = FamousConfig(), *, remat: bool = True,
            return_hidden: bool = False, compute_dtype=None) -> jax.Array:
    """inputs: int tokens (B, S) or float embeddings (B, S, D) for stub
    frontends.  Returns float32 logits (B, S, vocab) — or the final hidden
    states (B, S, D) when ``return_hidden`` (the chunked-CE loss computes
    logits tile-by-tile to avoid materialising the full logit tensor)."""
    x = _embed_inputs(params, inputs, cfg,
                      compute_dtype or params["final_norm"]["scale"].dtype)

    def unit_body(x, unit_params):
        for i, kind in enumerate(cfg.pattern_unit):
            x = apply_block(kind, unit_params[f"pos{i}"], x, cfg, fcfg)
        return x

    body = (jax.checkpoint(unit_body, policy=_remat_policy(cfg))
            if remat else unit_body)
    x, _ = jax.lax.scan(lambda c, p: (body(c, p), None), x, params["blocks"])
    for i, kind in enumerate(cfg.tail_layers):
        x = apply_block(kind, params[f"tail{i}"], x, cfg, fcfg)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x
    return logits_fn(params, x, cfg)


def logits_fn(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return layers.unembed_logits(params["embed"], x)
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      params["lm_head"]["w"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------


def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int, dtype,
                 shapes_only: bool = False, cache_kind: str = "contiguous",
                 page_size: int = 0, n_pages: int = 0, kv_dtype: str = "fp"):
    if kind == ATTN and cache_kind == "paged":
        # global-attention layers share a page pool; sliding-window and
        # recurrent layers are already O(window)/O(1) per slot and keep
        # their per-slot buffers even in paged mode.
        fn = (attention.paged_attn_cache_shape if shapes_only
              else attention.make_paged_attn_cache)
        return fn(cfg, n_pages, page_size, dtype, kv_dtype)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        fn = attention.attn_cache_shape if shapes_only else attention.make_attn_cache
        return fn(cfg, batch, max_seq, window, dtype)
    if kind == RGLRU:
        fn = rglru.rglru_cache_shape if shapes_only else rglru.make_rglru_cache
        return fn(cfg, batch, dtype)
    if kind == RWKV6:
        fn = rwkv6.rwkv_cache_shape if shapes_only else rwkv6.make_rwkv_cache
        return fn(cfg, batch, dtype)
    raise ValueError(kind)


def _stack_cache_tree(unit_caches: dict, n: int, shapes_only: bool):
    """Replicate a unit's cache tree n times along a leading scan dim."""
    if shapes_only:
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), unit_caches)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), unit_caches)


def make_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                shapes_only: bool = False, *, cache_kind: str = "contiguous",
                page_size: int = 0, n_pages: int = 0,
                kv_dtype: str = "fp") -> dict:
    """Build the per-layer decode caches.

    cache_kind="contiguous": every attention layer gets a per-slot
    ``(batch, max_seq | window, kv, dh)`` buffer (the seed baseline).
    cache_kind="paged": global-attention layers instead share a
    ``(n_pages, page_size, kv, dh)`` page pool addressed through the page
    table that ``decode_step`` receives at call time; memory then scales
    with live tokens, not ``batch x max_seq`` (see serve/paged.py).
    kv_dtype="int8" (paged only) stores those pools as int8 with fp32
    per-token scale pools riding the same page ids (see
    attention.make_paged_attn_cache); writes quantize, kernels dequantize
    in VMEM.
    """
    assert cache_kind in ("contiguous", "paged"), cache_kind
    assert kv_dtype in ("fp", "int8"), kv_dtype
    if kv_dtype == "int8":
        assert cache_kind == "paged", "kv_dtype='int8' requires paged caches"
    if cache_kind == "paged":
        assert page_size > 0 and n_pages > 0, (page_size, n_pages)
    unit = {f"pos{i}": _block_cache(k, cfg, batch, max_seq, dtype, shapes_only,
                                    cache_kind, page_size, n_pages, kv_dtype)
            for i, k in enumerate(cfg.pattern_unit)}
    caches: dict[str, Any] = {
        "blocks": _stack_cache_tree(unit, cfg.num_units, shapes_only)}
    for i, k in enumerate(cfg.tail_layers):
        caches[f"tail{i}"] = _block_cache(k, cfg, batch, max_seq, dtype,
                                          shapes_only, cache_kind, page_size,
                                          n_pages, kv_dtype)
    return caches


def param_axes(cfg: ModelConfig) -> dict:
    """Logical-axis tree matching ``init_params(model_spec(cfg), ...)``.

    Every ParamSpec already declares its axes (``stack_specs`` prepends
    "layers" for the scanned stack), so this is just the spec tree with
    shapes dropped — the mesh-placement twin of :func:`cache_axes`, used by
    the serving engine to shard params and caches consistently."""
    from repro.models import module
    return module.logical_axes(model_spec(cfg))


def cache_axes(cfg: ModelConfig, cache_kind: str = "contiguous",
               kv_dtype: str = "fp") -> dict:
    """Logical-axis tree matching ``make_caches(cfg, ..., cache_kind=,
    kv_dtype=)``: contiguous attention caches expose ("batch", seq,
    "kv_heads", "head_dim"); paged pools drop the slot axis and (for int8)
    add the scale-pool leaves, so the tree structure tracks the cache
    structure exactly."""
    def block_axes(kind):
        if kind == ATTN and cache_kind == "paged":
            return (attention.PAGED_ATTN_CACHE_AXES_INT8
                    if kv_dtype == "int8"
                    else attention.PAGED_ATTN_CACHE_AXES)
        if kind in (ATTN, LOCAL_ATTN):
            return attention.ATTN_CACHE_AXES
        if kind == RGLRU:
            return rglru.RGLRU_CACHE_AXES
        return rwkv6.RWKV_CACHE_AXES

    unit = {f"pos{i}": block_axes(k) for i, k in enumerate(cfg.pattern_unit)}
    stacked = jax.tree_util.tree_map(
        lambda ax: (None,) + tuple(ax), unit,
        is_leaf=lambda x: isinstance(x, tuple))
    axes: dict[str, Any] = {"blocks": stacked}
    for i, k in enumerate(cfg.tail_layers):
        axes[f"tail{i}"] = block_axes(k)
    return axes


def _apply_block_prefill(kind, p, x, cache, cfg, fcfg):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        a, cache = attention.apply_attn_prefill(p["attn"], n(p["ln1"], x),
                                                cache, cfg, fcfg, window=window)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RGLRU:
        a, cache = rglru.apply_rglru(p["rec"], n(p["ln1"], x), cfg, cache)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RWKV6:
        a, c_tm = rwkv6.apply_rwkv_time_mix(p["tm"], n(p["ln1"], x), cfg,
                                            cache={k: cache[k] for k in
                                                   ("s", "x_tm")})
        x = x + a
        h = n(p["ln2"], x)
        y, x_cm = rwkv6.apply_channel_mix(p["cm"], h, cfg)
        cache = {"s": c_tm["s"], "x_tm": c_tm["x_tm"], "x_cm": h[:, -1]}
        return x + y, cache
    raise ValueError(kind)


def _apply_block_decode(kind, p, x, cache, cache_len, cfg, fcfg,
                        page_table=None):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind == ATTN and page_table is not None:
        a, cache = attention.apply_attn_decode_paged(
            p["attn"], n(p["ln1"], x), cache, page_table, cache_len, cfg, fcfg)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        a, cache = attention.apply_attn_decode(p["attn"], n(p["ln1"], x),
                                               cache, cache_len, cfg, fcfg,
                                               window=window)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RGLRU:
        a, cache = rglru.apply_rglru_decode(p["rec"], n(p["ln1"], x), cfg=cfg,
                                            cache=cache)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RWKV6:
        a, c_tm = rwkv6.apply_rwkv_time_mix_decode(
            p["tm"], n(p["ln1"], x), {k: cache[k] for k in ("s", "x_tm")}, cfg)
        x = x + a
        h = n(p["ln2"], x)
        y, _ = rwkv6.apply_channel_mix(p["cm"], h, cfg,
                                       cache_x=cache["x_cm"])
        cache = {"s": c_tm["s"], "x_tm": c_tm["x_tm"], "x_cm": h[:, -1]}
        return x + y, cache
    raise ValueError(kind)


def prefill(params, inputs, caches, cfg: ModelConfig,
            fcfg: FamousConfig = FamousConfig(), compute_dtype=None):
    """Returns (last-position logits (B, vocab), new caches)."""
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    x = _embed_inputs(params, inputs, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new_caches[key] = _apply_block_prefill(
                kind, unit_params[key], x, unit_cache[key], cfg, fcfg)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new_caches[f"tail{i}"] = _apply_block_prefill(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], cfg, fcfg)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, x[:, -1:], cfg)[:, 0], new_caches


def _mask_state_update(kind: str, new_cache, old_cache, active):
    """Keep recurrent state frozen for non-``active`` slots.

    The decode executable runs over *every* slot each step (fixed batch);
    slots that are free or mid-chunked-prefill produce junk.  Junk K/V
    writes are harmless (masked by cache_len / overwritten by the next
    chunk), but recurrent state (RG-LRU h/conv, RWKV s/x_tm/x_cm) is read
    unconditionally and carried across prefill chunks — a junk update
    between chunks would corrupt it, so it only commits where ``active``.
    """
    if active is None or kind in (ATTN, LOCAL_ATTN):
        return new_cache
    return jax.tree_util.tree_map(
        lambda nc, oc: jnp.where(
            active.reshape((active.shape[0],) + (1,) * (nc.ndim - 1)),
            nc, oc.astype(nc.dtype)),
        new_cache, old_cache)


def decode_step(params, tokens, caches, cache_len, cfg: ModelConfig,
                fcfg: FamousConfig = FamousConfig(), compute_dtype=None,
                page_table=None, active=None):
    """tokens: (B,) int32 (or (B, D) embeddings); cache_len: (B,).
    page_table: optional (B, pages_per_slot) int32 — when given, global
    attention layers treat their caches as shared page pools (see
    ``make_caches(cache_kind="paged")``); when None, caches are the
    contiguous per-slot baseline.  active: optional (B,) bool — slots not
    decoding this step (free, or mid-chunked-prefill) keep their recurrent
    state untouched.  Returns (logits (B, vocab), new caches)."""
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    inputs = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
    x = _embed_inputs(params, inputs, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new = _apply_block_decode(
                kind, unit_params[key], x, unit_cache[key], cache_len, cfg,
                fcfg, page_table)
            new_caches[key] = _mask_state_update(kind, new, unit_cache[key],
                                                 active)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new = _apply_block_decode(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], cache_len, cfg,
            fcfg, page_table)
        new_caches[f"tail{i}"] = _mask_state_update(kind, new,
                                                    caches[f"tail{i}"], active)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, x, cfg)[:, 0], new_caches


def _apply_block_verify(kind, p, x, cache, cache_len, cfg, fcfg,
                        page_table=None):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind != ATTN:
        # Sliding-window rings write position p at slot p % ring — a verify
        # batch would destroy the oldest W entries before knowing how many
        # tokens survive, and recurrent state (RG-LRU, RWKV) cannot be
        # rolled back to an intermediate position.  The engine must fall
        # back to plain decode for these stacks (speculative_active False).
        raise ValueError(
            f"verify_step only supports global-attention layers, got {kind}")
    if page_table is not None:
        a, cache = attention.apply_attn_verify_paged(
            p["attn"], n(p["ln1"], x), cache, page_table, cache_len, cfg,
            fcfg)
    else:
        a, cache = attention.apply_attn_verify(
            p["attn"], n(p["ln1"], x), cache, cache_len, cfg, fcfg)
    x = x + a
    return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache


def verify_step(params, tokens, caches, cache_len, cfg: ModelConfig,
                fcfg: FamousConfig = FamousConfig(), compute_dtype=None,
                page_table=None):
    """Speculative verify: decode W tokens per slot in ONE forward.

    tokens: (B, W) int32 — row b is ``[last_token, draft_1..draft_{W-1}]``
    at absolute positions ``cache_len[b] + j`` (pad rows past a short
    draft are ignored by the caller); cache_len: (B,) valid cache entries
    BEFORE the first token, a runtime operand — one executable serves
    every draft-length mix.  Returns (logits (B, W, vocab), new caches):
    ``logits[b, j]`` is the next-token distribution after consuming
    ``tokens[b, :j+1]``, exactly what j+1 sequential ``decode_step`` calls
    would produce (causal attention makes the parallel and sequential
    activations identical), so the engine can accept the longest draft
    prefix the model agrees with and remain token-identical to plain
    decode.  ``W == 1`` degenerates to ``decode_step`` (without the
    recurrent/ring support — only all-ATTN stacks verify; see
    ``_apply_block_verify``).
    """
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    x = _embed_inputs(params, tokens, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new_caches[key] = _apply_block_verify(
                kind, unit_params[key], x, unit_cache[key], cache_len, cfg,
                fcfg, page_table)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new_caches[f"tail{i}"] = _apply_block_verify(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], cache_len, cfg,
            fcfg, page_table)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, x, cfg), new_caches


# ---------------------------------------------------------------------------
# serving: chunked prefill (the Scheduler/Runtime hot path)
# ---------------------------------------------------------------------------


def _read_slot_state(cache, slot, offset):
    """Slot row of a per-slot state tree, zeroed when ``offset == 0`` so a
    reused slot cannot leak the previous occupant's recurrent state into a
    fresh sequence (chunk 0 starts from zero state; later chunks carry)."""
    def read(buf):
        row = jax.lax.dynamic_slice(buf, (slot,) + (0,) * (buf.ndim - 1),
                                    (1,) + buf.shape[1:])
        return jnp.where(offset > 0, row, jnp.zeros_like(row))

    return jax.tree_util.tree_map(read, cache)


def _write_slot_state(cache, sub, slot):
    return jax.tree_util.tree_map(
        lambda d, s: jax.lax.dynamic_update_slice(
            d, s.astype(d.dtype), (slot,) + (0,) * (d.ndim - 1)),
        cache, sub)


def _apply_block_chunk(kind, p, x, cache, slot, offset, n_valid, cfg, fcfg,
                       page_table):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind == ATTN and page_table is not None:
        a, cache = attention.apply_attn_chunk_paged(
            p["attn"], n(p["ln1"], x), cache, page_table, slot, offset, cfg,
            fcfg)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        a, cache = attention.apply_attn_chunk(
            p["attn"], n(p["ln1"], x), cache, slot, offset, n_valid, cfg,
            fcfg, window=window)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RGLRU:
        sub = _read_slot_state(cache, slot, offset)
        a, sub = rglru.apply_rglru_chunk(p["rec"], n(p["ln1"], x), cfg, sub,
                                         n_valid)
        x = x + a
        return (x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg),
                _write_slot_state(cache, sub, slot))
    if kind == RWKV6:
        sub = _read_slot_state(cache, slot, offset)
        a, c_tm = rwkv6.apply_rwkv_time_mix_chunk(
            p["tm"], n(p["ln1"], x), {k: sub[k] for k in ("s", "x_tm")}, cfg,
            n_valid)
        x = x + a
        h = n(p["ln2"], x)
        y, _ = rwkv6.apply_channel_mix(p["cm"], h, cfg, cache_x=sub["x_cm"])
        x_cm = jax.lax.dynamic_slice_in_dim(h, n_valid - 1, 1, axis=1)[:, 0]
        sub = {"s": c_tm["s"], "x_tm": c_tm["x_tm"], "x_cm": x_cm}
        return x + y, _write_slot_state(cache, sub, slot)
    raise ValueError(kind)


def prefill_chunk(params, tokens, caches, slot, offset, n_valid,
                  cfg: ModelConfig, fcfg: FamousConfig = FamousConfig(),
                  page_table=None, compute_dtype=None):
    """One fixed-shape prefill chunk for a single slot of the batched caches.

    tokens: (1, C) int32 at absolute positions [offset, offset+C); only the
    first ``n_valid`` are real (the pad tail's state updates are masked to
    the identity, and its junk K/V is never read).  Writes K/V — contiguous
    stripe, ring buffer, or page pool — and recurrent state for ``slot``
    directly into the batched caches, replacing the old
    build-batch-1-then-scatter round trip, and carries recurrent state
    across chunks (``offset == 0`` starts from zero state).  ``slot``,
    ``offset`` and ``n_valid`` are runtime scalars: ONE executable serves
    every (slot, prompt length, chunk index) triple.  Returns the new
    caches only — prefill logits are dead weight (generation restarts by
    decoding the last prompt token), so the LM head is never computed.
    """
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    x = _embed_inputs(params, tokens, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new_caches[key] = _apply_block_chunk(
                kind, unit_params[key], x, unit_cache[key], slot, offset,
                n_valid, cfg, fcfg, page_table)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new_caches[f"tail{i}"] = _apply_block_chunk(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], slot, offset,
            n_valid, cfg, fcfg, page_table)
    return new_caches


# ---------------------------------------------------------------------------
# serving: slot-granular cache surgery (used by serve/engine.py)
# ---------------------------------------------------------------------------


def _write_slot(dst, src, slot, axis):
    return jax.lax.dynamic_update_slice_in_dim(
        dst, src.astype(dst.dtype), slot, axis=axis)


def _scatter_pages(pool, kv_seq, page_ids):
    """Scatter a contiguous (.., max_seq, kv, dh) K/V stripe into pool pages.

    pool: (..., n_pages, page_size, kv, dh); kv_seq: (..., max_seq, kv, dh);
    page_ids: (pages_per_slot,) int32, NULL-padded past the slot's live pages
    (the null page absorbs the padded chunks).  max_seq == pages_per_slot *
    page_size by construction (engine asserts max_seq % page_size == 0).
    """
    n_p = page_ids.shape[0]
    ps = pool.shape[-3]
    lead = kv_seq.shape[:-3]
    chunks = kv_seq.reshape(lead + (n_p, ps) + kv_seq.shape[-2:])
    axis = len(lead)
    idx = (slice(None),) * axis + (page_ids,)
    return pool.at[idx].set(chunks.astype(pool.dtype))


def _scatter_paged_kv(dst: dict, src_k, src_v, page_ids) -> dict:
    """Scatter a slot's contiguous fp K/V stripes into a paged ATTN pool,
    quantizing at write time when the pool is int8 (scale leaves present).
    Scale rows scatter with the *same* page_ids as their int8 rows, so the
    scale pool needs no allocator bookkeeping of its own."""
    if "k_scale" in dst:
        from repro.core import quant as quant_lib
        kq, ks = quant_lib.quantize(src_k, axis=-1)
        vq, vs = quant_lib.quantize(src_v, axis=-1)
        scatter_s = lambda pool, s: _scatter_pages(     # noqa: E731
            pool[..., None], s, page_ids)[..., 0]
        return {"k": _scatter_pages(dst["k"], kq, page_ids),
                "v": _scatter_pages(dst["v"], vq, page_ids),
                "k_scale": scatter_s(dst["k_scale"], ks),
                "v_scale": scatter_s(dst["v_scale"], vs)}
    return {n: _scatter_pages(dst[n], s, page_ids)
            for n, s in (("k", src_k), ("v", src_v))}


def write_prefill_to_slot(caches, one, slot, cfg: ModelConfig,
                          page_ids=None) -> dict:
    """Write a single-sequence prefill cache ``one`` (batch=1, contiguous)
    into slot ``slot`` of the batched ``caches``.

    Contiguous mode (page_ids=None): every leaf is a dynamic-update-slice on
    its slot axis (1 for the stacked block caches, 0 for tails).  Paged mode:
    global-attention K/V additionally reshape into page_size chunks and
    scatter to the slot's pages; all other leaves write their slot row as
    before.
    """
    def write_tree(dst, src, axis):
        return jax.tree_util.tree_map(
            lambda d, s: _write_slot(d, s, slot, axis), dst, src)

    out: dict[str, Any] = {"blocks": {}}
    for i, kind in enumerate(cfg.pattern_unit):
        key = f"pos{i}"
        dst, src = caches["blocks"][key], one["blocks"][key]
        if page_ids is not None and kind == ATTN:
            out["blocks"][key] = _scatter_paged_kv(dst, src["k"][:, 0],
                                                   src["v"][:, 0], page_ids)
        else:
            out["blocks"][key] = write_tree(dst, src, 1)
    for i, kind in enumerate(cfg.tail_layers):
        key = f"tail{i}"
        if page_ids is not None and kind == ATTN:
            out[key] = _scatter_paged_kv(caches[key], one[key]["k"][0],
                                         one[key]["v"][0], page_ids)
        else:
            out[key] = write_tree(caches[key], one[key], 0)
    return out


def clear_slot(caches, slot, cfg: ModelConfig, paged: bool = False) -> dict:
    """Zero slot ``slot``'s per-slot cache state (stale-state hygiene for
    length-1 admissions that skip prefill).  In paged mode global-attention
    pools are left alone: the slot's pages were already freed and any stale
    page content is unreachable (the page table row is null and reads are
    cache_len-masked)."""
    def zero_tree(tree, axis):
        def z(buf):
            idx = (slice(None),) * axis + (slot,)
            return buf.at[idx].set(jnp.zeros((), buf.dtype))
        return jax.tree_util.tree_map(z, tree)

    out: dict[str, Any] = {"blocks": {}}
    for i, kind in enumerate(cfg.pattern_unit):
        key = f"pos{i}"
        if paged and kind == ATTN:
            out["blocks"][key] = caches["blocks"][key]
        else:
            out["blocks"][key] = zero_tree(caches["blocks"][key], 1)
    for i, kind in enumerate(cfg.tail_layers):
        key = f"tail{i}"
        if paged and kind == ATTN:
            out[key] = caches[key]
        else:
            out[key] = zero_tree(caches[key], 0)
    return out

"""Generic block-stack model covering all assigned architectures.

The layer stack is ``pattern_unit`` repeated ``num_units`` times via
``jax.lax.scan`` over stacked parameters (keeps the HLO size O(unit), not
O(layers) — essential for the 64-layer/1T-param dry-runs), plus an explicit
tail for patterns that do not divide the layer count (recurrentgemma's 26 = 8
× (R,R,A) + (R,R)).

Three entry points:
  * ``forward``        — full-sequence logits (training / encoder).
  * ``prefill``        — forward + build per-layer caches (serving).
  * ``decode_step``    — one token against the caches (serving decode).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV6, ModelConfig
from repro.core.famous import FamousConfig
from repro.models import attention, layers, moe, rglru, rwkv6
from repro.models.module import ParamSpec, stack_specs
from repro.parallel.incontext import constrain_residual

# ---------------------------------------------------------------------------
# parameter spec
# ---------------------------------------------------------------------------


def _ffn_spec(cfg: ModelConfig):
    if cfg.num_experts:
        return moe.moe_spec(cfg)
    gated = cfg.act in ("silu", "gelu") and cfg.norm == "rmsnorm"
    return layers.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, gated=gated)


def block_spec(kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if kind in (ATTN, LOCAL_ATTN):
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "attn": attention.attn_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "ffn": _ffn_spec(cfg),
        }
    if kind == RGLRU:
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "rec": rglru.rglru_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "ffn": _ffn_spec(cfg),
        }
    if kind == RWKV6:
        return {
            "ln1": layers.norm_spec(d, cfg.norm),
            "tm": rwkv6.rwkv6_spec(cfg),
            "ln2": layers.norm_spec(d, cfg.norm),
            "cm": rwkv6.channel_mix_spec(cfg),
        }
    raise ValueError(kind)


def model_spec(cfg: ModelConfig) -> dict:
    unit = {f"pos{i}": block_spec(k, cfg) for i, k in enumerate(cfg.pattern_unit)}
    spec: dict[str, Any] = {
        "embed": layers.embed_spec(cfg.vocab_size, cfg.d_model),
        "blocks": stack_specs(unit, cfg.num_units),
        "final_norm": layers.norm_spec(cfg.d_model, cfg.norm),
    }
    for i, k in enumerate(cfg.tail_layers):
        spec[f"tail{i}"] = block_spec(k, cfg)
    if not cfg.tie_embeddings:
        spec["lm_head"] = {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                           scale=0.02)
        }
    return spec


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------


def _apply_ffn(p, x, cfg: ModelConfig):
    if cfg.num_experts:
        return moe.apply_moe(p, x, cfg)
    return layers.apply_mlp(p, x, cfg.act)


def apply_block(kind: str, p: dict, x: jax.Array, cfg: ModelConfig,
                fcfg: FamousConfig, q_offset: int = 0) -> jax.Array:
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        x = constrain_residual(x, cfg.num_heads)
        x = x + attention.apply_attn(p["attn"], n(p["ln1"], x), cfg, fcfg,
                                     window=window, q_offset=q_offset)
        x = constrain_residual(x, cfg.num_heads)
        h = constrain_residual(n(p["ln2"], x), cfg.num_heads)
        return x + constrain_residual(_apply_ffn(p["ffn"], h, cfg),
                                      cfg.num_heads)
    if kind == RGLRU:
        x = x + rglru.apply_rglru(p["rec"], n(p["ln1"], x), cfg)
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg)
    if kind == RWKV6:
        x = x + rwkv6.apply_rwkv_time_mix(p["tm"], n(p["ln1"], x), cfg)
        y, _ = rwkv6.apply_channel_mix(p["cm"], n(p["ln2"], x), cfg)
        return x + y
    raise ValueError(kind)


def _embed_inputs(params, inputs, cfg: ModelConfig, dtype):
    if jnp.issubdtype(inputs.dtype, jnp.integer):
        return layers.embed_lookup(params["embed"], inputs, dtype)
    return inputs.astype(dtype)  # frontend stub: precomputed embeddings


def _remat_policy(cfg: ModelConfig):
    """§Perf iteration K3 (REFUTED, kept for the record): saving the MoE
    expert-FFN intermediates under save_only_these_names did not remove the
    backward's expert-weight all-gathers (XLA re-gathers for dbuf/dW anyway)
    and cost +36 GiB/device of saved activations — policy disabled."""
    return None


def forward(params: dict, inputs: jax.Array, cfg: ModelConfig,
            fcfg: FamousConfig = FamousConfig(), *, remat: bool = True,
            return_hidden: bool = False, compute_dtype=None) -> jax.Array:
    """inputs: int tokens (B, S) or float embeddings (B, S, D) for stub
    frontends.  Returns float32 logits (B, S, vocab) — or the final hidden
    states (B, S, D) when ``return_hidden`` (the chunked-CE loss computes
    logits tile-by-tile to avoid materialising the full logit tensor)."""
    x = _embed_inputs(params, inputs, cfg,
                      compute_dtype or params["final_norm"]["scale"].dtype)

    def unit_body(x, unit_params):
        for i, kind in enumerate(cfg.pattern_unit):
            x = apply_block(kind, unit_params[f"pos{i}"], x, cfg, fcfg)
        return x

    body = (jax.checkpoint(unit_body, policy=_remat_policy(cfg))
            if remat else unit_body)
    x, _ = jax.lax.scan(lambda c, p: (body(c, p), None), x, params["blocks"])
    for i, kind in enumerate(cfg.tail_layers):
        x = apply_block(kind, params[f"tail{i}"], x, cfg, fcfg)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x
    return logits_fn(params, x, cfg)


def logits_fn(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return layers.unembed_logits(params["embed"], x)
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      params["lm_head"]["w"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------


def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int, dtype,
                 shapes_only: bool = False):
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        fn = attention.attn_cache_shape if shapes_only else attention.make_attn_cache
        return fn(cfg, batch, max_seq, window, dtype)
    if kind == RGLRU:
        fn = rglru.rglru_cache_shape if shapes_only else rglru.make_rglru_cache
        return fn(cfg, batch, dtype)
    if kind == RWKV6:
        fn = rwkv6.rwkv_cache_shape if shapes_only else rwkv6.make_rwkv_cache
        return fn(cfg, batch, dtype)
    raise ValueError(kind)


def _stack_cache_tree(unit_caches: dict, n: int, shapes_only: bool):
    """Replicate a unit's cache tree n times along a leading scan dim."""
    if shapes_only:
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), unit_caches)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), unit_caches)


def make_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                shapes_only: bool = False) -> dict:
    unit = {f"pos{i}": _block_cache(k, cfg, batch, max_seq, dtype, shapes_only)
            for i, k in enumerate(cfg.pattern_unit)}
    caches: dict[str, Any] = {
        "blocks": _stack_cache_tree(unit, cfg.num_units, shapes_only)}
    for i, k in enumerate(cfg.tail_layers):
        caches[f"tail{i}"] = _block_cache(k, cfg, batch, max_seq, dtype,
                                          shapes_only)
    return caches


def cache_axes(cfg: ModelConfig) -> dict:
    def block_axes(kind):
        if kind in (ATTN, LOCAL_ATTN):
            return attention.ATTN_CACHE_AXES
        if kind == RGLRU:
            return rglru.RGLRU_CACHE_AXES
        return rwkv6.RWKV_CACHE_AXES

    unit = {f"pos{i}": block_axes(k) for i, k in enumerate(cfg.pattern_unit)}
    stacked = jax.tree_util.tree_map(
        lambda ax: (None,) + tuple(ax), unit,
        is_leaf=lambda x: isinstance(x, tuple))
    axes: dict[str, Any] = {"blocks": stacked}
    for i, k in enumerate(cfg.tail_layers):
        axes[f"tail{i}"] = block_axes(k)
    return axes


def _apply_block_prefill(kind, p, x, cache, cfg, fcfg):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        a, cache = attention.apply_attn_prefill(p["attn"], n(p["ln1"], x),
                                                cache, cfg, fcfg, window=window)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RGLRU:
        a, cache = rglru.apply_rglru(p["rec"], n(p["ln1"], x), cfg, cache)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RWKV6:
        a, c_tm = rwkv6.apply_rwkv_time_mix(p["tm"], n(p["ln1"], x), cfg,
                                            cache={k: cache[k] for k in
                                                   ("s", "x_tm")})
        x = x + a
        h = n(p["ln2"], x)
        y, x_cm = rwkv6.apply_channel_mix(p["cm"], h, cfg)
        cache = {"s": c_tm["s"], "x_tm": c_tm["x_tm"], "x_cm": h[:, -1]}
        return x + y, cache
    raise ValueError(kind)


def _apply_block_decode(kind, p, x, cache, cache_len, cfg, fcfg):
    n = functools.partial(layers.apply_norm, kind=cfg.norm)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == LOCAL_ATTN else 0
        a, cache = attention.apply_attn_decode(p["attn"], n(p["ln1"], x),
                                               cache, cache_len, cfg, fcfg,
                                               window=window)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RGLRU:
        a, cache = rglru.apply_rglru_decode(p["rec"], n(p["ln1"], x), cfg=cfg,
                                            cache=cache)
        x = x + a
        return x + _apply_ffn(p["ffn"], n(p["ln2"], x), cfg), cache
    if kind == RWKV6:
        a, c_tm = rwkv6.apply_rwkv_time_mix_decode(
            p["tm"], n(p["ln1"], x), {k: cache[k] for k in ("s", "x_tm")}, cfg)
        x = x + a
        h = n(p["ln2"], x)
        y, _ = rwkv6.apply_channel_mix(p["cm"], h, cfg,
                                       cache_x=cache["x_cm"])
        cache = {"s": c_tm["s"], "x_tm": c_tm["x_tm"], "x_cm": h[:, -1]}
        return x + y, cache
    raise ValueError(kind)


def prefill(params, inputs, caches, cfg: ModelConfig,
            fcfg: FamousConfig = FamousConfig(), compute_dtype=None):
    """Returns (last-position logits (B, vocab), new caches)."""
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    x = _embed_inputs(params, inputs, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new_caches[key] = _apply_block_prefill(
                kind, unit_params[key], x, unit_cache[key], cfg, fcfg)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new_caches[f"tail{i}"] = _apply_block_prefill(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], cfg, fcfg)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, x[:, -1:], cfg)[:, 0], new_caches


def decode_step(params, tokens, caches, cache_len, cfg: ModelConfig,
                fcfg: FamousConfig = FamousConfig(), compute_dtype=None):
    """tokens: (B,) int32 (or (B, D) embeddings); cache_len: (B,).
    Returns (logits (B, vocab), new caches)."""
    dtype = compute_dtype or params["final_norm"]["scale"].dtype
    inputs = tokens[:, None] if tokens.ndim == 1 else tokens[:, None, :]
    x = _embed_inputs(params, inputs, cfg, dtype)

    def unit_body(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for i, kind in enumerate(cfg.pattern_unit):
            key = f"pos{i}"
            x, new_caches[key] = _apply_block_decode(
                kind, unit_params[key], x, unit_cache[key], cache_len, cfg, fcfg)
        return x, new_caches

    x, new_block_caches = jax.lax.scan(
        unit_body, x, (params["blocks"], caches["blocks"]))
    new_caches = {"blocks": new_block_caches}
    for i, kind in enumerate(cfg.tail_layers):
        x, new_caches[f"tail{i}"] = _apply_block_decode(
            kind, params[f"tail{i}"], x, caches[f"tail{i}"], cache_len, cfg,
            fcfg)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return logits_fn(params, x, cfg)[:, 0], new_caches

"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

Training/prefill uses an associative scan over time (state is elementwise in
the feature dim, so the scan element is O(width)); decode carries (h, conv
ring) state.  The Pallas chunked-scan kernel (kernels/scan) is the TPU
hot-path analogue; this module is its ref and the XLA dry-run path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.module import ParamSpec

_C = 8.0  # RG-LRU decay sharpness constant (Griffin §2.4)


def rglru_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.lru_width or d
    w = cfg.conv_width
    return {
        "w_x": ParamSpec((d, r), ("embed", "mlp")),
        "w_gate": ParamSpec((d, r), ("embed", "mlp")),
        "conv_w": ParamSpec((w, r), (None, "mlp"), scale=0.5),
        "conv_b": ParamSpec((r,), ("mlp",), init="zeros"),
        "w_a": ParamSpec((r, r), ("mlp", None)),
        "b_a": ParamSpec((r,), (None,), init="zeros"),
        "w_i": ParamSpec((r, r), ("mlp", None)),
        "b_i": ParamSpec((r,), (None,), init="zeros"),
        # Λ parameterised so a = exp(-C*softplus(Λ)·r_t) starts near 0.9..0.99
        "lam": ParamSpec((r,), (None,), init="uniform", scale=1.0),
        "w_out": ParamSpec((r, d), ("mlp", "embed")),
    }


def _gates(p, xc):
    """Recurrence gate r_t and input gate i_t from the conv output."""
    rg = jax.nn.sigmoid(
        jnp.einsum("bsr,rk->bsk", xc, p["w_a"].astype(xc.dtype)).astype(jnp.float32)
        + p["b_a"].astype(jnp.float32))
    ig = jax.nn.sigmoid(
        jnp.einsum("bsr,rk->bsk", xc, p["w_i"].astype(xc.dtype)).astype(jnp.float32)
        + p["b_i"].astype(jnp.float32))
    return rg, ig


def _decay(p, rg):
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)
    return a, jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))


def rglru_scan(p, xc, h0=None, n_valid=None):
    """xc: (B, S, R) conv output -> recurrence output (B, S, R) float32.

    h0: optional (B, R) carried state (chunked prefill) — injected as
    ``h_1 = a_1 h0 + b_1``.  n_valid: optional () int32 — positions
    >= n_valid are pad: their update is masked to the identity
    (a=1, b=0), so ``h[:, -1]`` is exactly the state after the last
    *real* token.
    """
    rg, ig = _gates(p, xc)
    a, gain = _decay(p, rg)
    b = gain * (ig * xc.astype(jnp.float32))
    if n_valid is not None:
        valid = (jnp.arange(xc.shape[1]) < n_valid)[None, :, None]
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_step(p, xc_t, h_prev):
    """One decode step. xc_t: (B, R); h_prev: (B, R) f32 -> (h_t, h_t)."""
    xc = xc_t[:, None, :]
    rg, ig = _gates(p, xc)
    a, gain = _decay(p, rg)
    b = gain * (ig * xc.astype(jnp.float32))
    h = a[:, 0] * h_prev + b[:, 0]
    return h


def _conv1d(p, x, state=None, n_valid=None):
    """Causal depthwise temporal conv, width W. x: (B, S, R).
    state: (B, W-1, R) previous inputs for decode; returns (y, new_state).
    n_valid: optional () int32 — the carried state is the W-1 inputs ending
    at the last *real* position (pad tail excluded)."""
    w = p["conv_w"].astype(jnp.float32)  # (W, R)
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), jnp.float32)
    else:
        pad = state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    y = y + p["conv_b"].astype(jnp.float32)
    if n_valid is None:
        new_state = xp[:, -(W - 1):]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, n_valid, W - 1, axis=1)
    return y, new_state


def make_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), jnp.float32),
    }


def rglru_cache_shape(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.lru_width or cfg.d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, r), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, r), jnp.float32),
    }


RGLRU_CACHE_AXES = {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}


def apply_rglru(p: dict, x: jax.Array, cfg: ModelConfig,
                cache: dict | None = None):
    """x: (B, S, D) -> (B, S, D); if cache given, runs prefill and returns
    (out, new_cache)."""
    xb = jnp.einsum("bsd,dr->bsr", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dr->bsr", x, p["w_gate"].astype(x.dtype))
    xc, conv_state = _conv1d(p, xb, None if cache is None else cache["conv"])
    h = rglru_scan(p, xc)
    y = jax.nn.gelu(gate.astype(jnp.float32)) * h
    out = jnp.einsum("bsr,rd->bsd", y.astype(x.dtype), p["w_out"].astype(x.dtype))
    if cache is None:
        return out
    new_cache = {"h": h[:, -1], "conv": conv_state}
    return out, new_cache


def apply_rglru_chunk(p: dict, x: jax.Array, cfg: ModelConfig, cache: dict,
                      n_valid):
    """Chunked prefill: like ``apply_rglru(cache=...)`` but *carrying* the
    recurrent state h across chunks (fresh prefill starts from zero; chunk
    c > 0 resumes from the slot's state) and masking pad positions
    >= n_valid so their state updates are the identity.  x: (1, C, D);
    cache: {"h","conv"}.  Returns (out, new cache)."""
    xb = jnp.einsum("bsd,dr->bsr", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dr->bsr", x, p["w_gate"].astype(x.dtype))
    xc, conv_state = _conv1d(p, xb, cache["conv"], n_valid=n_valid)
    h = rglru_scan(p, xc, h0=cache["h"], n_valid=n_valid)
    y = jax.nn.gelu(gate.astype(jnp.float32)) * h
    out = jnp.einsum("bsr,rd->bsd", y.astype(x.dtype),
                     p["w_out"].astype(x.dtype))
    return out, {"h": h[:, -1], "conv": conv_state}


def apply_rglru_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One token. x: (B, 1, D) -> (out (B,1,D), new_cache)."""
    xb = jnp.einsum("bsd,dr->bsr", x, p["w_x"].astype(x.dtype))
    gate = jnp.einsum("bsd,dr->bsr", x, p["w_gate"].astype(x.dtype))
    xc, conv_state = _conv1d(p, xb, cache["conv"])
    h = rglru_step(p, xc[:, 0], cache["h"])
    y = jax.nn.gelu(gate[:, 0].astype(jnp.float32)) * h
    out = jnp.einsum("br,rd->bd", y.astype(x.dtype), p["w_out"].astype(x.dtype))
    return out[:, None], {"h": h, "conv": conv_state}

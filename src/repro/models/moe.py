"""Token-choice top-k Mixture-of-Experts FFN (grok-1, kimi-k2).

GShard/GSPMD-style *grouped dense dispatch*: tokens are split into G groups;
each group dispatches into a per-group expert buffer of capacity C via a
(G, S_g, E, C) one-hot einsum.  Everything is dense einsums, which GSPMD
partitions perfectly (groups on the dp axes, experts on "model" = expert
parallelism with all-to-all routing inserted by XLA).  A scatter/gather
formulation was tried first and rejected: GSPMD replicates scatter operands,
costing ~190 GiB/device on grok-1 (see EXPERIMENTS.md §Perf).

Capacity inflation is bounded: C = ceil(cf · S_g · K / E) per group, so the
buffer is cf·K·T token-slots globally.  Tokens beyond an expert's capacity
within their group are dropped (standard GShard semantics); priority is
earlier-rank choice first, then sequence order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.module import ParamSpec
from repro.parallel.incontext import constrain


def moe_spec(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        # Router stays REPLICATED (K1): sharding its tiny (d, E) matrix over
        # "model" forced a (G,S,E) all-gather before top_k plus a ~2 GiB dx
        # all-reduce per layer — 8 s/step on kimi-k2 for a 2.7M-param matmul.
        "router": ParamSpec((d, e), ("embed", None), scale=0.02,
                            dtype=jnp.float32),
        # Expert weights: experts on "model" (EP), d_ff on "data" (K2) —
        # the FSDP gather of the d_model dim moved 4x more bytes than the
        # partial-sum all-reduce this layout pays on the down-projection.
        "w_in": ParamSpec((e, d, f), ("experts", None, "expert_ff")),
        "w_gate": ParamSpec((e, d, f), ("experts", None, "expert_ff")),
        "w_out": ParamSpec((e, f, d), ("experts", "expert_ff", None)),
    }


def _group_shape(T: int, target_group: int = 256) -> tuple[int, int]:
    """Split T tokens into (G, S_g) with T = G*S_g and S_g ~ target."""
    sg = min(target_group, T)
    while T % sg:
        sg -= 1
    return T // sg, sg


def router_dispatch(logits: jax.Array, K: int, capacity_factor: float,
                    softcap: float = 0.0):
    """logits: (G, S, E) f32.  Returns (dispatch (G,S,E,C) bf16,
    combine (G,S,E,C) f32, aux metrics)."""
    G, S, E = logits.shape
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    C = max(4, int(-(-capacity_factor * S * K // E)))
    C = min(C, S)
    gates = jax.nn.softmax(logits, axis=-1)               # (G,S,E)
    topw, topi = jax.lax.top_k(logits, K)                 # (G,S,K)
    topw = jax.nn.softmax(topw, axis=-1)

    running = jnp.zeros((G, 1, E), jnp.int32)             # used capacity
    dispatch = jnp.zeros((G, S, E, C), jnp.bfloat16)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    for k in range(K):
        mask_k = jax.nn.one_hot(topi[..., k], E, dtype=jnp.int32)   # (G,S,E)
        pos_k = running + jnp.cumsum(mask_k, axis=1) - mask_k       # (G,S,E)
        keep = (pos_k < C) & (mask_k > 0)
        oh = jax.nn.one_hot(jnp.where(keep, pos_k, C), C + 1,
                            dtype=jnp.bfloat16)[..., :C]            # (G,S,E,C)
        oh = oh * mask_k[..., None].astype(jnp.bfloat16)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * topw[..., k, None, None]
        running = running + jnp.sum(mask_k, axis=1, keepdims=True)
    frac = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=(0, 1, 2))
    aux = E * jnp.sum(frac * gates.mean((0, 1)))          # load-balance loss
    return dispatch, combine, aux


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              return_aux: bool = False):
    """x: (B, S, D) -> (B, S, D).  Grouped dense top-k routing."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G, Sg = _group_shape(T)
    xg = constrain(x.reshape(G, Sg, D), ("batch", None, None))

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    logits = constrain(logits, ("batch", None, None))
    dispatch, combine, aux = router_dispatch(
        logits, K, cfg.capacity_factor, cfg.logit_softcap)
    dispatch = constrain(dispatch, ("batch", None, "experts", None))

    buf = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    buf = constrain(buf, ("batch", "experts", None, None))
    h_in = jnp.einsum("gecd,edf->gecf", buf, p["w_in"].astype(x.dtype))
    h_gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
    h = layers.act_fn(cfg.act)(h_gate) * h_in
    # named for the MoE remat policy (K3): saving h/out_buf stops the remat
    # pass from re-all-gathering every expert weight a second time.
    h = checkpoint_name(h, "moe_hidden")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_out"].astype(x.dtype))
    out_buf = constrain(out_buf, ("batch", "experts", None, None))
    out_buf = checkpoint_name(out_buf, "moe_out")

    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), out_buf)
    y = y.reshape(B, S, D)
    if return_aux:
        return y, aux
    return y


def aux_load_balance_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean fraction * prob)."""
    B, S, D = x.shape
    T = B * S
    G, Sg = _group_shape(T)
    xg = x.reshape(G, Sg, D)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    _, _, aux = router_dispatch(logits, cfg.experts_per_token,
                                cfg.capacity_factor, cfg.logit_softcap)
    return aux

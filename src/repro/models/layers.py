"""Shared layers: norms, RoPE, MLPs, embeddings — pure-functional JAX."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import ParamSpec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_spec(d: int, kind: str) -> dict:
    spec = {"scale": ParamSpec((d,), (None,), init="ones")}
    if kind == "layernorm":
        spec["bias"] = ParamSpec((d,), (None,), init="zeros")
    return spec


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    """Dtype-preserving norm: statistics accumulate in f32 (``dtype=`` on the
    reduction) but the full tensor is never upcast — a full f32 copy of a
    bf16 hidden state would otherwise escape the remat scan as a
    loop-hoisted 2× activation stack (observed: +15 GiB/device on a 48-layer
    dry-run)."""
    dt = x.dtype
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(x), -1, keepdims=True, dtype=jnp.float32)
        inv = jax.lax.rsqrt(ms + eps).astype(dt)
        return x * inv * p["scale"].astype(dt)
    mu = jnp.mean(x, -1, keepdims=True, dtype=jnp.float32)
    xc = x - mu.astype(dt)
    var = jnp.mean(jnp.square(xc), -1, keepdims=True, dtype=jnp.float32)
    y = xc * jax.lax.rsqrt(var + eps).astype(dt)
    return y * p["scale"].astype(dt) + p["bias"].astype(dt)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    """Per-head RMSNorm over head_dim (qwen3 qk-norm). x: (..., H, dh)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh), positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freq[None, :]  # (S, half)
        ang = ang[None, :, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * freq  # (B, S, half)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP / FFN (the paper's position-wise feed-forward network)
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu_sq": lambda x: jnp.square(jax.nn.relu(x))}[name]


def mlp_spec(d: int, d_ff: int, act: str, gated: bool = True) -> dict:
    spec = {
        "w_in": ParamSpec((d, d_ff), ("embed", "mlp")),
        "w_out": ParamSpec((d_ff, d), ("mlp", "embed")),
    }
    if gated:
        spec["w_gate"] = ParamSpec((d, d_ff), ("embed", "mlp"))
    return spec


def apply_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int) -> dict:
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"),
                                   init="embed", scale=0.02)}


def embed_lookup(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    # one-hot-free gather; GSPMD turns this into a sharded gather over vocab
    return p["embedding"].astype(dtype)[tokens]


def unembed_logits(p: dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      p["embedding"].astype(jnp.float32))

"""repro: FAMOUS (tiled flexible dense MHA) as a multi-pod JAX framework."""
__version__ = "1.0.0"

"""Pallas launch contract checker — FAMOUS's synthesis-time resource and
tiling validation (§IV-B) applied to every ``pallas_call``.

The FPGA design statically guarantees that tile sizes divide the matrix
dims it will serve and that the BRAM/URAM banks the tiles occupy fit the
device; violations are caught at synthesis, not on the board.  The Pallas
analogue used to be "crash at trace time with a shape error three layers
deep" — or worse, silently read garbage from an out-of-bounds block.  This
module validates, at launch time and against the *actual* operands:

* every ``BlockSpec`` block shape divides its array dim (no silent
  partial tiles);
* every ``index_map`` takes exactly ``grid rank + num_scalar_prefetch``
  arguments and its outputs stay in bounds over the grid (full
  enumeration for small grids, corner sampling beyond
  :data:`GRID_ENUM_CAP` points);
* the launch's *output* grids cover their arrays completely (a partially
  written output is garbage in the uncovered blocks);
* the per-grid-step VMEM footprint estimated from block shapes + dtypes
  (input/output blocks double-buffered for the DMA pipeline, plus VMEM
  scratch) fits a configurable budget — the on-chip memory accounting of
  the paper, with ``REPRO_VMEM_BUDGET_BYTES`` standing in for the part's
  BRAM capacity.

Index maps that read scalar-prefetched operands (the page-table kernels)
are evaluated with the real host values when the launch is outside
``jax.jit``; under tracing the prefetched values are unknown, so those
specs get arity/divisibility checks only — recorded, never guessed.

Enablement: off by default (zero overhead in production), on via
``REPRO_KERNEL_CHECK=1``, :func:`enable`, or the :func:`checking` context
manager; the test suite switches it on globally in ``tests/conftest.py``.
All violations of one launch are aggregated into a single
:class:`KernelContractError`.
"""
from __future__ import annotations

import contextlib
import functools
import inspect
import itertools
import math
import os

import numpy as np

GRID_ENUM_CAP = 16384          # full index_map enumeration up to this many
DEFAULT_VMEM_BUDGET = 16 * 2 ** 20   # 16 MiB — one TPUv4 core's VMEM

_FORCED: bool | None = None    # tri-state override of the env switch


class KernelContractError(ValueError):
    """A Pallas launch violated its BlockSpec/grid/VMEM contract."""


def kernel_check_enabled() -> bool:
    if _FORCED is not None:
        return _FORCED
    return os.environ.get("REPRO_KERNEL_CHECK", "0").lower() \
        not in ("", "0", "false")


def enable() -> None:
    global _FORCED
    _FORCED = True


def disable() -> None:
    global _FORCED
    _FORCED = False


@contextlib.contextmanager
def checking(on: bool = True):
    """Scoped enable/disable, restoring the previous state on exit."""
    global _FORCED
    prev = _FORCED
    _FORCED = on
    try:
        yield
    finally:
        _FORCED = prev


def vmem_budget() -> int:
    return int(os.environ.get("REPRO_VMEM_BUDGET_BYTES",
                              DEFAULT_VMEM_BUDGET))


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _is_tracer(x) -> bool:
    import jax
    return isinstance(x, jax.core.Tracer)


def _mem_space_name(obj) -> str:
    ms = getattr(obj, "memory_space", None)
    return "" if ms is None else str(getattr(ms, "value", ms)).lower()


def _kernel_name(kernel) -> str:
    if isinstance(kernel, functools.partial):
        kernel = kernel.func
    return getattr(kernel, "__name__", repr(kernel))


class _PrefetchProbe:
    """Stand-in handed to index_maps for a scalar-prefetch operand.

    Wraps the operand's host value when it is concrete; records whether
    the map actually indexed into it, so value-dependent checks can be
    skipped (not guessed) when the operand is a tracer.
    """

    def __init__(self, operand):
        self.touched = False
        self.concrete = not _is_tracer(operand)
        self._arr = np.asarray(operand) if self.concrete else None
        self._shape = tuple(getattr(operand, "shape", ()))

    def __getitem__(self, idx):
        self.touched = True
        if self.concrete:
            return self._arr[idx]
        return 0    # placeholder; the caller discards the result

    @property
    def shape(self):
        return self._shape


def _index_map_arity(index_map):
    """(required positional params, accepts extras) — defaulted trailing
    params (the ``lambda ..., group=group:`` closure idiom) are allowed on
    top of the grid+prefetch arguments."""
    try:
        sig = inspect.signature(index_map)
    except (TypeError, ValueError):    # builtins etc. — cannot introspect
        return None, True
    required = 0
    varargs = False
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            if p.default is p.empty:
                required += 1
        elif p.kind == p.VAR_POSITIONAL:
            varargs = True
    return required, varargs


def _grid_points(grid):
    """(iterator of grid index tuples, exhaustive?) — every point for
    small grids, the corners beyond :data:`GRID_ENUM_CAP`."""
    total = math.prod(grid) if grid else 1
    if total <= GRID_ENUM_CAP:
        return itertools.product(*[range(g) for g in grid]), True
    corners = itertools.product(*[(0, g - 1) if g > 1 else (0,)
                                  for g in grid])
    return corners, False


def _block_bytes(block_shape, shape, dtype) -> int:
    eff = [s if b is None else b for b, s in zip(block_shape, shape)] \
        if block_shape is not None else list(shape)
    return int(np.prod([max(int(e), 1) for e in eff], dtype=np.int64)
               * np.dtype(dtype).itemsize) if eff else \
        int(np.dtype(dtype).itemsize)


# --------------------------------------------------------------------------
# the checks
# --------------------------------------------------------------------------

def _check_spec(errors, *, role, i, spec, shape, grid, probes, exhaustive_pts,
                require_coverage):
    """Validate one BlockSpec against one array shape."""
    where = f"{role}[{i}] (shape {tuple(shape)})"
    block = getattr(spec, "block_shape", None)
    if block is None:      # whole-array spec: trivially divides and covers
        return
    block = tuple(block)
    if len(block) != len(shape):
        errors.append(f"{where}: block rank {len(block)} != array rank "
                      f"{len(shape)} (block {block})")
        return
    for d, (b, s) in enumerate(zip(block, shape)):
        if b is not None and (b <= 0 or s % b):
            errors.append(f"{where}: block dim {d} = {b} does not divide "
                          f"array dim {s} — partial tiles read/write out "
                          f"of bounds unless explicitly masked")

    index_map = getattr(spec, "index_map", None)
    if index_map is None:
        return
    required, varargs = _index_map_arity(index_map)
    expected = len(grid) + len(probes)
    if required is not None and required != expected and not varargs:
        errors.append(f"{where}: index_map takes {required} required "
                      f"arg(s) but the launch provides {len(grid)} grid "
                      f"indices + {len(probes)} scalar-prefetch "
                      f"operand(s) = {expected}")
        return

    points, exhaustive = _grid_points(grid)
    exhaustive = exhaustive and exhaustive_pts
    seen: set = set()
    value_checked = True
    for pt in points:
        for pr in probes:
            pr.touched = False
        try:
            out = index_map(*pt, *probes)
        except Exception as e:    # noqa: BLE001 — any failure is a finding
            errors.append(f"{where}: index_map raised {type(e).__name__} "
                          f"at grid point {pt}: {e}")
            return
        out = tuple(out) if isinstance(out, tuple) else (out,)
        if len(out) != len(block):
            errors.append(f"{where}: index_map returns {len(out)} "
                          f"indices for a rank-{len(block)} block")
            return
        if any(pr.touched and not pr.concrete for pr in probes):
            # value depends on traced prefetch data: unverifiable here
            value_checked = False
            continue
        static = []
        for d, (c, b, s) in enumerate(zip(out, block, shape)):
            try:
                ci = int(c)
            except (TypeError, ValueError):
                value_checked = False
                static.append(None)
                continue
            static.append(ci)
            if b is None:
                continue
            if ci < 0 or (ci + 1) * b > s:
                errors.append(
                    f"{where}: index_map output {ci} at grid point {pt} "
                    f"puts block dim {d} out of bounds "
                    f"(needs ({ci}+1)*{b} <= {s})")
                return
        if None not in static:
            seen.add(tuple(static))

    if require_coverage and exhaustive and value_checked:
        needed = itertools.product(
            *[range(s // b) if b else range(1)
              for b, s in zip(block, shape)])
        missing = [p for p in needed if p not in seen]
        if missing:
            errors.append(
                f"{where}: grid does not cover the array — "
                f"{len(missing)} of {math.prod(max(s // b, 1) if b else 1 for b, s in zip(block, shape))} "
                f"output block(s) never written (first missing: "
                f"{missing[0]})")


def check_launch(*, name, grid, in_specs, out_specs, out_shape,
                 scratch_shapes=(), num_scalar_prefetch=0, args=()):
    """Validate one launch; raises :class:`KernelContractError` listing
    every violation.  ``args`` are the call's actual operands, scalar-
    prefetch operands first."""
    grid = (grid,) if isinstance(grid, int) else tuple(grid or ())
    outs = out_shape if isinstance(out_shape, (list, tuple)) else [out_shape]
    ospecs = list(out_specs) if isinstance(out_specs, (list, tuple)) \
        else [out_specs]
    scalar_args = list(args[:num_scalar_prefetch])
    operands = list(args[num_scalar_prefetch:])
    probes = [_PrefetchProbe(a) for a in scalar_args]

    errors: list = []
    in_specs = list(in_specs or ())
    if len(in_specs) != len(operands):
        errors.append(f"{len(in_specs)} in_spec(s) for {len(operands)} "
                      f"non-prefetch operand(s)")
    if len(ospecs) != len(outs):
        errors.append(f"{len(ospecs)} out_spec(s) for {len(outs)} "
                      f"out_shape(s)")

    vmem = 0
    pairs = [("in_specs", i, s, o.shape, getattr(o, "dtype", np.float32),
              False)
             for i, (s, o) in enumerate(zip(in_specs, operands))]
    pairs += [("out_specs", i, s, tuple(o.shape), o.dtype, True)
              for i, (s, o) in enumerate(zip(ospecs, outs))]
    for role, i, spec, shape, dtype, is_out in pairs:
        _check_spec(errors, role=role, i=i, spec=spec, shape=tuple(shape),
                    grid=grid, probes=probes, exhaustive_pts=True,
                    require_coverage=is_out)
        if "smem" not in _mem_space_name(spec):
            # input/output blocks are double-buffered by the Pallas
            # pipeline: the live footprint is 2x the block
            vmem += 2 * _block_bytes(getattr(spec, "block_shape", None),
                                     shape, dtype)
    for sc in scratch_shapes or ():
        if "vmem" in _mem_space_name(sc) or not _mem_space_name(sc):
            vmem += _block_bytes(None, getattr(sc, "shape", ()),
                                 getattr(sc, "dtype", np.float32))

    budget = vmem_budget()
    if vmem > budget:
        errors.append(f"estimated per-step VMEM footprint {vmem} B "
                      f"(double-buffered blocks + scratch) exceeds the "
                      f"budget of {budget} B "
                      f"(REPRO_VMEM_BUDGET_BYTES)")

    if errors:
        raise KernelContractError(
            f"pallas kernel contract violation(s) in `{name}` "
            f"(grid {grid}):\n  - " + "\n  - ".join(errors))


def check_pallas_launch(kernel, call_kwargs: dict, args: tuple) -> None:
    """Entry point for :func:`repro.kernels.pallas_compat.pallas_call`:
    unpack a ``pl.pallas_call`` keyword set (either ``grid=...`` style or
    a ``grid_spec=PrefetchScalarGridSpec(...)``) and validate."""
    grid_spec = call_kwargs.get("grid_spec")
    if grid_spec is not None:
        grid = grid_spec.grid
        in_specs = grid_spec.in_specs
        out_specs = grid_spec.out_specs
        scratch = grid_spec.scratch_shapes
        npf = getattr(grid_spec, "num_scalar_prefetch", 0) or 0
    else:
        grid = call_kwargs.get("grid", ())
        in_specs = call_kwargs.get("in_specs", ())
        out_specs = call_kwargs.get("out_specs", ())
        scratch = call_kwargs.get("scratch_shapes", ())
        npf = 0
    check_launch(name=_kernel_name(kernel), grid=grid, in_specs=in_specs,
                 out_specs=out_specs, out_shape=call_kwargs.get("out_shape"),
                 scratch_shapes=scratch, num_scalar_prefetch=npf, args=args)

"""AST linter for jit-unsafe anti-patterns in the serving/runtime code.

The serving hot loop gets its O(1)-executables and low-dispatch-overhead
guarantees from a handful of disciplines that nothing used to enforce:
device values must not be pulled to the host one element at a time, device
state must not be rebuilt with per-element ``.at[].set`` scatters inside
Python loops (one dispatch each, ~1.3 ms on CPU — more than a tiny-model
forward), ``jax.jit`` must be told which arguments are static, and the
Scheduler must stay pure policy (importing ``jax`` there would let device
state leak into what is by design host-only code).  This module turns each
discipline into a rule:

``RA001 host-sync-in-loop``
    ``int()`` / ``float()`` / ``np.asarray()`` / ``np.array()`` /
    ``jax.device_get()`` applied to a device-tainted value inside a Python
    loop (or comprehension).  Each call is one blocking device->host sync;
    hoist to a single ``np.asarray`` pull before the loop.
``RA002 eager-scatter-in-loop``
    ``x.at[...].set(...)`` (or ``.add``/``.mul``/...) inside a Python
    loop.  Each is a full dispatch + device array rebuild; batch the
    updates or keep the state in host numpy.
``RA003 jit-missing-static``
    ``jax.jit(f)`` without ``static_argnames``/``static_argnums`` where
    ``f`` (resolvable in the same module) has ``str``- or ``bool``-typed
    parameters (default value or annotation) — values jit would either
    fail on or silently retrace per distinct value.
``RA004 impure-scheduler``
    any ``jax``/``jaxlib`` import in a module declared host-pure
    (``PURE_MODULES``: the scheduler, the drafter, and the ``obs/``
    observability stack).  Zero allowlist entries by design.

Device taint is a deliberately simple per-function analysis: expressions
rooted at ``jnp.*`` / ``jax.numpy`` / ``jax.lax`` / ``jax.random`` are
device; ``self.X`` is device when any assignment in the class binds it to
a device expression; a local takes the taint of what it was last assigned
(``np.asarray(...)``/``int(...)``/``float(...)`` launder back to host —
that single call *is* the blessed hoisted sync).  Precision over recall:
the linter only reports what it can see is device-backed, so host numpy
bookkeeping (page tables, slot masks) never false-positives.

Findings are compared against a checked-in baseline
(``analysis/lint_baseline.txt``): entries are ``path::rule::normalised
source line`` fingerprints, stable across unrelated edits.  New findings
fail CI; baseline entries that no longer match are reported as stale.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re

RULES = {
    "RA001": "host-sync-in-loop: per-iteration device->host sync "
             "(int()/float()/np.asarray() on a device value inside a "
             "Python loop); hoist one np.asarray() pull above the loop",
    "RA002": "eager-scatter-in-loop: .at[...].set()-style scatter inside "
             "a Python loop dispatches once per element; batch the "
             "updates or keep this state in host numpy",
    "RA003": "jit-missing-static: jax.jit of a function with str/bool "
             "parameters but no static_argnames/static_argnums",
    "RA004": "impure-scheduler: pure-policy module must not import jax",
}

# modules (repo-relative under src/repro) contractually free of jax —
# RA004 admits no baseline entries for these.  The obs/ modules are here
# so observability can never introduce a device sync (docs/observability.md).
PURE_MODULES = ("serve/scheduler.py", "serve/draft.py",
                "obs/metrics.py", "obs/trace.py", "obs/runtime.py")

_DEVICE_ROOTS = ("jnp", "jax.numpy", "jax.lax", "jax.random", "jax.nn")
_SYNC_CALLS = ("int", "float", "np.asarray", "np.array", "numpy.asarray",
               "numpy.array", "jax.device_get")
_HOST_PRODUCERS = _SYNC_CALLS + ("np.zeros", "np.ones", "np.arange",
                                 "numpy.zeros", "numpy.ones", "len")
_SCATTER_METHODS = ("set", "add", "subtract", "sub", "multiply", "mul",
                    "divide", "div", "power", "min", "max", "apply")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str       # repo-relative, e.g. "serve/engine.py"
    line: int
    rule: str
    detail: str
    snippet: str    # whitespace-normalised source line (the fingerprint key)

    @property
    def fingerprint(self) -> str:
        return f"{self.path}::{self.rule}::{self.snippet}"

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} {self.detail}\n"
                f"    {self.snippet}")


def _dotted(node) -> str | None:
    """``a.b.c`` attribute/name chain as a string, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _normalise(line: str) -> str:
    return re.sub(r"\s+", " ", line.strip())


def _is_device_root(dotted: str) -> bool:
    return any(dotted == r or dotted.startswith(r + ".")
               for r in _DEVICE_ROOTS)


class _ClassAttrs(ast.NodeVisitor):
    """First pass over a ClassDef: which ``self.X`` attrs are ever bound
    to a device expression anywhere in the class."""

    def __init__(self):
        self.device_attrs: set = set()

    def visit_Assign(self, node):
        taint = _expr_device(node.value, set(), set())
        for tgt in node.targets:
            if (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self" and taint):
                self.device_attrs.add(tgt.attr)
        self.generic_visit(node)


def _expr_device(node, tainted_locals: set, device_attrs: set) -> bool:
    """Does this expression reference anything device-backed?"""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Attribute, ast.Name)):
            d = _dotted(sub)
            if d is None:
                continue
            if _is_device_root(d):
                return True
            root = d.split(".")[0]
            if root in tainted_locals:
                return True
            if (d.startswith("self.")
                    and d.split(".")[1] in device_attrs):
                return True
        # x.at[...] only exists on jax arrays
        if isinstance(sub, ast.Attribute) and sub.attr == "at":
            return True
    return False


class _FunctionLinter(ast.NodeVisitor):
    """Per-function walk tracking loop depth and local device taint."""

    def __init__(self, module: "_ModuleLinter", device_attrs: set):
        self.m = module
        self.device_attrs = device_attrs
        self.tainted: set = set()
        self.loop_depth = 0

    # -- taint bookkeeping --------------------------------------------------
    def _rhs_taint(self, value) -> str:
        if isinstance(value, ast.Call):
            fn = _dotted(value.func)
            if fn in _HOST_PRODUCERS:
                return "host"
        if _expr_device(value, self.tainted, self.device_attrs):
            return "device"
        return "host"

    def _bind(self, target, taint: str):
        if isinstance(target, ast.Name):
            if taint == "device":
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, taint)

    def visit_Assign(self, node):
        self.generic_visit(node)
        taint = self._rhs_taint(node.value)
        for tgt in node.targets:
            self._bind(tgt, taint)

    def visit_AnnAssign(self, node):
        self.generic_visit(node)
        if node.value is not None:
            self._bind(node.target, self._rhs_taint(node.value))

    def visit_AugAssign(self, node):
        self.generic_visit(node)
        if self._rhs_taint(node.value) == "device":
            self._bind(node.target, "device")

    # -- loops ---------------------------------------------------------------
    def _loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = visit_While = visit_AsyncFor = _loop
    visit_ListComp = visit_SetComp = visit_DictComp = _loop
    visit_GeneratorExp = _loop

    # -- nested defs start a fresh scope outside any loop --------------------
    def _nested(self, node):
        inner = _FunctionLinter(self.m, self.device_attrs)
        for stmt in node.body if not isinstance(node, ast.Lambda) \
                else [node.body]:
            inner.visit(stmt)

    visit_FunctionDef = visit_AsyncFunctionDef = visit_Lambda = _nested

    # -- the rules -----------------------------------------------------------
    def visit_Call(self, node):
        self.generic_visit(node)
        fn = _dotted(node.func)
        # RA001: per-iteration host sync
        if (self.loop_depth and fn in _SYNC_CALLS and node.args
                and _expr_device(node.args[0], self.tainted,
                                 self.device_attrs)):
            self.m.report(node, "RA001",
                          f"`{fn}()` syncs a device value every iteration")
        # RA002: x.at[...].set(...) in a loop
        if (self.loop_depth and isinstance(node.func, ast.Attribute)
                and node.func.attr in _SCATTER_METHODS
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            self.m.report(node, "RA002",
                          f"`.at[...].{node.func.attr}()` scatter inside "
                          f"a Python loop")
        # RA003: jax.jit without static declarations
        if fn in ("jax.jit", "jit") and (fn == "jax.jit"
                                         or "jit" in self.m.jax_names):
            kw = {k.arg for k in node.keywords}
            if not ({"static_argnames", "static_argnums"} & kw):
                self._check_jit_target(node)

    def _check_jit_target(self, node):
        if not node.args or not isinstance(node.args[0], ast.Name):
            return
        fdef = self.m.funcdefs.get(node.args[0].id)
        if fdef is None:
            return
        static = _static_params(fdef)
        if static:
            self.m.report(
                node, "RA003",
                f"`jax.jit({node.args[0].id})` but parameter(s) "
                f"{', '.join(sorted(static))} are str/bool-typed; declare "
                f"static_argnames")


def _static_params(fdef) -> list:
    """Parameters of ``fdef`` whose default or annotation is str/bool."""
    out = []
    args = fdef.args
    pos = args.posonlyargs + args.args
    defaults = [None] * (len(pos) - len(args.defaults)) + list(args.defaults)
    pairs = list(zip(pos, defaults)) + \
        list(zip(args.kwonlyargs, args.kw_defaults))
    for a, d in pairs:
        if (isinstance(d, ast.Constant) and isinstance(d.value, (str, bool))):
            out.append(a.arg)
        elif (isinstance(a.annotation, ast.Name)
                and a.annotation.id in ("str", "bool")):
            out.append(a.arg)
    return out


class _ModuleLinter:
    def __init__(self, path: str, src: str):
        self.path = path
        self.lines = src.splitlines()
        self.findings: list = []
        self.tree = ast.parse(src, filename=path)
        self.funcdefs = {n.name: n for n in ast.walk(self.tree)
                         if isinstance(n, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))}
        # names imported from jax (``from jax import jit``)
        self.jax_names: set = set()
        for n in ast.walk(self.tree):
            if isinstance(n, ast.ImportFrom) and (n.module or "") == "jax":
                self.jax_names |= {a.asname or a.name for a in n.names}

    def report(self, node, rule: str, detail: str):
        line = getattr(node, "lineno", 0)
        snippet = _normalise(self.lines[line - 1]) if \
            0 < line <= len(self.lines) else ""
        self.findings.append(Finding(self.path, line, rule, detail, snippet))

    def run(self) -> list:
        self._check_purity()
        for node in self.tree.body:
            self._lint_scope(node, device_attrs=set())
        return self.findings

    def _check_purity(self):
        if not any(self.path == p or self.path.endswith("/" + p)
                   for p in PURE_MODULES):
            return
        for n in ast.walk(self.tree):
            mods = []
            if isinstance(n, ast.Import):
                mods = [a.name for a in n.names]
            elif isinstance(n, ast.ImportFrom):
                mods = [n.module or ""]
            for mod in mods:
                if mod.split(".")[0] in ("jax", "jaxlib"):
                    self.report(n, "RA004",
                                f"pure-policy module imports `{mod}`")

    def _lint_scope(self, node, device_attrs: set):
        if isinstance(node, ast.ClassDef):
            collector = _ClassAttrs()
            collector.visit(node)
            for stmt in node.body:
                self._lint_scope(stmt, collector.device_attrs)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            linter = _FunctionLinter(self, device_attrs)
            for stmt in node.body:
                linter.visit(stmt)
        elif isinstance(node, (ast.If, ast.Try, ast.With)):
            for stmt in ast.iter_child_nodes(node):
                self._lint_scope(stmt, device_attrs)


def lint_source(src: str, path: str = "<string>") -> list:
    """Lint one module's source; ``path`` is used for reporting and for
    the purity contract (match against :data:`PURE_MODULES`)."""
    return _ModuleLinter(path, src).run()


def lint_paths(root: str) -> list:
    """Lint every ``*.py`` under ``root`` (the ``src/repro`` package
    directory); finding paths are reported relative to ``root``."""
    findings = []
    for dirpath, _dirnames, filenames in sorted(os.walk(root)):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            with open(full, encoding="utf-8") as f:
                findings.extend(lint_source(f.read(), rel))
    return findings


# --------------------------------------------------------------------------
# baseline / allowlist
# --------------------------------------------------------------------------

BASELINE_FILE = os.path.join(os.path.dirname(__file__), "lint_baseline.txt")


def load_baseline(path: str = BASELINE_FILE) -> set:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        return {ln.strip() for ln in f
                if ln.strip() and not ln.lstrip().startswith("#")}


def write_baseline(findings, path: str = BASELINE_FILE) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write("# repro.analysis lint baseline — accepted findings.\n"
                "# One `path::rule::normalised source line` per line;\n"
                "# regenerate with `python -m repro.analysis "
                "--update-baseline`.\n")
        for fp in sorted({x.fingerprint for x in findings}):
            f.write(fp + "\n")


def compare_to_baseline(findings, baseline: set):
    """(new findings, stale baseline entries).  RA004 findings in
    :data:`PURE_MODULES` are never baselined-away — purity admits no
    allowlist."""
    fps = {x.fingerprint for x in findings}
    new = [x for x in findings
           if x.fingerprint not in baseline or x.rule == "RA004"]
    stale = sorted(baseline - fps)
    return new, stale

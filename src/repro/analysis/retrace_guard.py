"""Retrace guard: fail when a steady-state region compiles anything new.

The serving engine's whole design (PR 3) is that after warmup the hot
loop runs exactly two executables — one chunked-prefill step and one
decode step — for *any* request mix.  That O(1)-executables invariant
used to be asserted ad hoc (``sum(census.values()) <= 3`` sprinkled over
tests and benchmarks), which checks an absolute count including warmup
rather than the property that actually matters: **a warm region must not
compile**.  This context manager snapshots a compilation census on entry
and raises :class:`RetraceError` on exit if anything grew:

    with retrace_guard(engine):          # engine already warmed
        engine.run(requests)             # steady-state: zero new compiles

Any subject with a ``compilations`` attribute works: the engine (census
dict), :class:`repro.core.flexible.FlexibleAttention` (int counter), or a
zero-arg callable returning either.  An :class:`repro.obs.runtime.Observer`
(which exports the engine census through its ``census()`` method) and a
flat metrics-registry snapshot (``Observer.snapshot()`` — the
``repro_engine_compilations{exec="..."}`` gauges are extracted) are also
accepted, so a guard can read the census through the observability seam
instead of holding an engine reference.  ``allow=`` admits a known number
of deliberate compilations (e.g. a first-use cold path inside an
otherwise warm region).
"""
from __future__ import annotations

import contextlib
import re

# Observer.snapshot() key for one compilation gauge, e.g.
#   repro_engine_compilations{exec="decode"}
_SNAPSHOT_KEY = re.compile(r'^repro_engine_compilations\{exec="([^"]*)"\}$')


class RetraceError(AssertionError):
    """A guarded steady-state region compiled new executables."""


def _from_snapshot(snap: dict) -> dict | None:
    """Extract the compilation gauges from a flat metrics snapshot
    (``{'name{labels}': value}``); None when the dict is not one."""
    out = {}
    for key, value in snap.items():
        if not isinstance(key, str):
            return None
        m = _SNAPSHOT_KEY.match(key)
        if m:
            out[m.group(1)] = int(value)
    return out if out else None


def census(subject) -> dict:
    """Normalise a subject's compilation census to ``{key: count}``."""
    c = getattr(subject, "compilations", None)
    if c is None:
        # an Observer: its census() refreshes + returns the engine census
        cm = getattr(subject, "census", None)
        if callable(cm) and not isinstance(subject, dict):
            c = cm()
        elif isinstance(subject, dict):
            # a flat registry snapshot (Observer.snapshot()) — pull the
            # repro_engine_compilations{exec=...} gauges out of it.  A
            # snapshot with no census gauges registered is an empty census,
            # not a {exec: count} dict of unrelated metric samples.
            c = _from_snapshot(subject)
            if c is None:
                snapshot_like = any(isinstance(k, str) and "{" in k
                                    for k in subject)
                c = {} if snapshot_like else dict(subject)
        elif callable(subject):
            c = subject()
    if callable(c):
        c = c()
    if isinstance(c, dict):
        extracted = _from_snapshot(c)
        if extracted is not None:
            c = extracted
        return {str(k): int(v) for k, v in c.items()}
    if isinstance(c, (int, float)):
        return {"compilations": int(c)}
    raise TypeError(
        f"retrace_guard subject {subject!r} has no usable `compilations` "
        f"census (need an int, a dict, an Observer, a registry snapshot, "
        f"or a callable returning one)")


@contextlib.contextmanager
def retrace_guard(*subjects, allow: int = 0, label: str = ""):
    """Assert that no subject compiles more than ``allow`` new
    executables (total, across all subjects) inside the ``with`` body."""
    if not subjects:
        raise ValueError("retrace_guard needs at least one subject")
    before = [census(s) for s in subjects]
    yield
    grew = []
    total = 0
    for s, b in zip(subjects, before):
        a = census(s)
        for key in sorted(set(a) | set(b)):
            delta = a.get(key, 0) - b.get(key, 0)
            if delta > 0:
                total += delta
                grew.append(f"{type(s).__name__}.{key}: "
                            f"{b.get(key, 0)} -> {a.get(key, 0)}")
    if total > allow:
        where = f" [{label}]" if label else ""
        raise RetraceError(
            f"steady-state region{where} compiled {total} new "
            f"executable(s) (allow={allow}):\n  " + "\n  ".join(grew))

"""Static-analysis subsystem: the software analogue of FAMOUS's
synthesis-time resource checks.

Three passes, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.lint` — an AST linter over ``src/repro`` for
  jit-unsafe anti-patterns (per-iteration host syncs, eager ``.at[].set``
  scatters in Python loops, ``jax.jit`` calls missing static-arg
  declarations, and the scheduler purity contract), with a checked-in
  baseline so accepted legacy findings don't block CI while new
  regressions do.
* :mod:`repro.analysis.kernel_check` — a Pallas launch contract checker
  hooked through :func:`repro.kernels.pallas_compat.pallas_call`: block
  shapes must divide array dims, index_maps must match the grid rank and
  stay in bounds, output grids must cover their arrays, and the per-step
  VMEM footprint must fit a configurable budget (the on-chip BRAM/URAM
  accounting of the paper, §IV-B).
* :mod:`repro.analysis.retrace_guard` — a context manager that fails when
  a steady-state region (warm decode loop, warm prefix-cache serving)
  compiles anything new, replacing ad-hoc executable-count assertions.
"""
from repro.analysis.kernel_check import (KernelContractError, checking,
                                         kernel_check_enabled)
from repro.analysis.lint import Finding, lint_paths, lint_source
from repro.analysis.retrace_guard import RetraceError, retrace_guard

__all__ = [
    "Finding", "KernelContractError", "RetraceError", "checking",
    "kernel_check_enabled", "lint_paths", "lint_source", "retrace_guard",
]

"""``python -m repro.analysis`` — run every static-analysis pass.

Passes (any can be skipped; exit status is nonzero if any ran and
failed):

1. **lint** — AST rules over ``src/repro`` compared against the
   checked-in baseline (``analysis/lint_baseline.txt``): new findings
   fail, stale baseline entries are reported.  ``--update-baseline``
   rewrites the baseline to the current findings instead of failing.
2. **kernel-check** — every Pallas kernel family launched once at tiny
   shapes in interpret mode with the contract checker enabled: BlockSpec
   divisibility, index_map arity/bounds, output-grid coverage and the
   VMEM budget are validated against live launches, not just fixtures.
3. **retrace** — tiny warmed serving engines — plain, speculative (verify
   executable), int8 paged, and (when >= 2 devices are visible) TP=2
   mesh-sharded — must each serve a fresh batch under
   :func:`repro.analysis.retrace_guard.retrace_guard` with zero new
   compilations (the O(1)-executables invariant from PR 3).

``scripts/ci.sh`` runs this before the test suite.
"""
from __future__ import annotations

import argparse
import sys


def run_lint(update_baseline: bool) -> int:
    import os

    from repro.analysis import lint

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings = lint.lint_paths(root)
    if update_baseline:
        lint.write_baseline(findings)
        print(f"lint: baseline rewritten with {len(findings)} finding(s) "
              f"-> {lint.BASELINE_FILE}")
        return 0
    new, stale = lint.compare_to_baseline(findings, lint.load_baseline())
    for f in new:
        print(f"lint: NEW {f}")
    for fp in stale:
        print(f"lint: stale baseline entry (fixed? remove it): {fp}")
    n_base = len(findings) - len(new)
    print(f"lint: {len(findings)} finding(s): {len(new)} new, "
          f"{n_base} baselined, {len(stale)} stale baseline entr(ies)")
    return 1 if new else 0


def run_kernel_check() -> int:
    """Launch each kernel family once, tiny, with checking on."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import kernel_check
    from repro.kernels.attention.mha import mha_backward, mha_forward
    from repro.kernels.decode.chunk_prefill import (chunk_prefill,
                                                    paged_chunk_prefill,
                                                    paged_chunk_prefill_int8)
    from repro.kernels.decode.decode_attn import (
        decode_attention, paged_decode_attention,
        paged_decode_attention_int8)
    from repro.kernels.qkv.qkv_proj import matmul_tiled
    from repro.kernels.scan.linear_scan import rglru_scan, wkv6_scan

    rng = np.random.default_rng(0)

    def arr(*shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    def qarr(*shape):
        return jnp.asarray(rng.integers(-127, 128, shape), jnp.int8)

    def sarr(*shape):
        return jnp.asarray(rng.uniform(1e-3, 2e-2, shape), jnp.float32)

    failures = 0
    with kernel_check.checking(True):
        launches = []
        q, k, v = arr(2, 16, 8), arr(2, 16, 8), arr(2, 16, 8)
        launches.append(("attention/mha_forward", lambda: mha_forward(
            q, k, v, block_q=8, block_k=8, interpret=True,
            return_lse=True)))
        out, lse = mha_forward(q, k, v, block_q=8, block_k=8,
                               interpret=True, return_lse=True)
        launches.append(("attention/mha_backward", lambda: mha_backward(
            q, k, v, out, lse, arr(2, 16, 8), block_q=8, block_k=8,
            interpret=True)))
        launches.append(("qkv/matmul_tiled", lambda: matmul_tiled(
            arr(16, 32), arr(32, 16), block_t=8, block_f=8, block_d=16,
            interpret=True)))
        launches.append(("decode/decode_attention", lambda: decode_attention(
            arr(2, 2, 8), arr(2, 16, 8), arr(2, 16, 8),
            jnp.array([5, 9], jnp.int32), block_k=8, interpret=True)))
        pt = jnp.asarray(np.arange(1, 9, dtype=np.int32).reshape(2, 4))
        launches.append(("decode/paged_decode_attention",
                         lambda: paged_decode_attention(
                             arr(2, 1, 2, 8), arr(9, 4, 1, 8),
                             arr(9, 4, 1, 8), pt,
                             jnp.array([5, 9], jnp.int32), interpret=True)))
        launches.append(("decode/paged_decode_attention_int8",
                         lambda: paged_decode_attention_int8(
                             arr(2, 1, 2, 8), qarr(9, 4, 1, 8),
                             qarr(9, 4, 1, 8), sarr(9, 4, 1), sarr(9, 4, 1),
                             pt, jnp.array([5, 9], jnp.int32),
                             interpret=True)))
        launches.append(("decode/chunk_prefill", lambda: chunk_prefill(
            arr(2, 8, 8), arr(2, 16, 8), arr(2, 16, 8), 4, chunk=4,
            block_k=8, interpret=True)))
        launches.append(("decode/paged_chunk_prefill",
                         lambda: paged_chunk_prefill(
                             arr(2, 1, 8, 8), arr(9, 4, 1, 8),
                             arr(9, 4, 1, 8), pt, 4, chunk=4,
                             interpret=True)))
        launches.append(("decode/paged_chunk_prefill_int8",
                         lambda: paged_chunk_prefill_int8(
                             arr(2, 1, 8, 8), qarr(9, 4, 1, 8),
                             qarr(9, 4, 1, 8), sarr(9, 4, 1), sarr(9, 4, 1),
                             pt, 4, chunk=4, interpret=True)))
        launches.append(("scan/rglru_scan", lambda: rglru_scan(
            arr(2, 8, 8), arr(2, 8, 8), block_r=8, block_s=4,
            interpret=True)))
        launches.append(("scan/wkv6_scan", lambda: wkv6_scan(
            arr(2, 8, 8), arr(2, 8, 8), arr(2, 8, 8),
            -jnp.abs(arr(2, 8, 8)), arr(2, 8), chunk=4, interpret=True)))
        for name, launch in launches:
            try:
                jax.block_until_ready(launch())
                print(f"kernel-check: ok {name}")
            except kernel_check.KernelContractError as e:
                print(f"kernel-check: FAIL {name}: {e}")
                failures += 1
    return 1 if failures else 0


def run_retrace() -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.retrace_guard import RetraceError, retrace_guard
    from repro.configs.base import get_config, shrink
    from repro.core.famous import FamousConfig
    from repro.models import module, transformer
    from repro.serve.engine import Request, ServingEngine

    cfg = shrink(get_config("qwen2-7b"))
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           n_slots=2, max_seq=32, chunk=8)
    rng = np.random.default_rng(0)

    def reqs(rid0):
        return [Request(rid=rid0 + i, max_new=3,
                        tokens=list(rng.integers(0, cfg.vocab_size, 5 + i)))
                for i in range(3)]

    engine.run(reqs(0))              # warmup compiles the two executables
    try:
        with retrace_guard(engine, label="warm decode loop"):
            engine.run(reqs(10))
    except RetraceError as e:
        print(f"retrace: FAIL {e}")
        return 1
    print(f"retrace: ok — warm engine served a fresh batch with zero new "
          f"compilations (census {engine.compilations})")
    # speculative engine: the verify executable replaces decode; a warm
    # engine must serve a fresh mixed workload (varying prompts, so draft
    # lengths 0..draft_k all occur) with zero new compilations
    spec = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                         n_slots=2, max_seq=32, chunk=8,
                         speculative=True, draft_k=3)
    spec.run(reqs(20))
    try:
        with retrace_guard(spec, label="warm speculative decode loop"):
            spec.run(reqs(30))
    except RetraceError as e:
        print(f"retrace: FAIL {e}")
        return 1
    print(f"retrace: ok — warm speculative engine served a fresh batch with "
          f"zero new compilations (census {spec.compilations})")
    # int8 paged engine: quantize-on-write and the scale-pool operands ride
    # the same executables — kv_dtype is a cache-structure choice, not a
    # static jit argument, so the census must stay O(1) here too
    q8 = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                       n_slots=2, max_seq=32, chunk=8,
                       cache_kind="paged", page_size=8, kv_dtype="int8")
    q8.run(reqs(40))
    try:
        with retrace_guard(q8, label="warm int8 paged decode loop"):
            q8.run(reqs(50))
    except RetraceError as e:
        print(f"retrace: FAIL {e}")
        return 1
    print(f"retrace: ok — warm int8 paged engine served a fresh batch with "
          f"zero new compilations (census {q8.compilations})")
    # mesh-sharded engine: out_shardings and the device_put placement must
    # not fork executables — a warm TP=2 engine serves a fresh batch with
    # zero new compilations too.  Needs >= 2 devices; the ci.sh
    # `== multi-device ==` stage runs this module under
    # XLA_FLAGS=--xla_force_host_platform_device_count=8.
    if jax.device_count() >= 2:
        from repro.launch.mesh import make_serving_mesh
        tp2 = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                            n_slots=2, max_seq=32, chunk=8,
                            mesh=make_serving_mesh(tp=2))
        tp2.run(reqs(60))
        try:
            with retrace_guard(tp2, label="warm TP=2 sharded decode loop"):
                tp2.run(reqs(70))
        except RetraceError as e:
            print(f"retrace: FAIL {e}")
            return 1
        print(f"retrace: ok — warm TP=2 sharded engine served a fresh batch "
              f"with zero new compilations (census {tp2.compilations})")
    else:
        print("retrace: note — TP=2 sharded pass skipped (1 visible device; "
              "the ci.sh multi-device stage forces 8)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro static-analysis passes (lint, kernel contract "
                    "check, retrace guard)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the lint baseline instead of failing on "
                         "new findings")
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--no-kernel-check", action="store_true")
    ap.add_argument("--no-retrace", action="store_true")
    args = ap.parse_args(argv)

    status = 0
    if not args.no_lint:
        print("== repro.analysis: lint ==")
        status |= run_lint(args.update_baseline)
    if not args.no_kernel_check:
        print("== repro.analysis: kernel contract check ==")
        status |= run_kernel_check()
    if not args.no_retrace:
        print("== repro.analysis: retrace guard ==")
        status |= run_retrace()
    print("repro.analysis: " + ("FAILED" if status else "clean"))
    return status


if __name__ == "__main__":
    sys.exit(main())

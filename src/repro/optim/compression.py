"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

At 1000+-node scale the pod-interconnect (DCN or long ICI hops) is the
scarcest bandwidth; gradients crossing it are compressed 4× (f32→int8,
per-tensor symmetric scale) with an error-feedback residual so compression
noise does not accumulate (Seide et al., Karimireddy et al.).

Used by ``train_step`` when ``RunConfig.grad_compression`` is on and the mesh
has a "pod" axis: gradients are reduced *within* a pod at full precision by
the usual psum, then the pod-axis reduction runs through ``compressed_psum``
under ``shard_map``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress(g: jax.Array, residual: jax.Array | None = None):
    """f32 -> (int8, scale). Error feedback folds the residual in first."""
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_residual = gf - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_tree(grads, mesh, axis: str = "pod", residuals=None):
    """All-reduce a gradient pytree over ``axis`` in int8 with error feedback.

    Returns (mean-reduced grads, new residuals).  Must be called on values
    sharded over ``axis`` (i.e. inside shard_map, or with grads replicated on
    the other axes).
    """
    n = mesh.shape[axis]

    def one(g, r):
        gf = g.astype(jnp.float32) + (0.0 if r is None else r)
        # agree on a common scale first (a scalar pmax is ~free), so the
        # int32 accumulation of int8 payloads is exact.
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
        scale = jnp.maximum(amax, 1e-20) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return (total.astype(jnp.float32) * scale / n).astype(g.dtype), new_r

    if residuals is None:
        residuals = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_r

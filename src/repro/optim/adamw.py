"""AdamW with optional reduced-precision moments, built for multi-hundred-B
models: moment dtype is configurable (fp32 default, bf16 for the ≥300B
configs where fp32 m/v would not fit HBM — see DESIGN.md §7 memory note).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    grad_clip: float = 1.0


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig,
                  lr_scale: jax.Array | float = 1.0):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm}


def cosine_schedule(step, *, base_lr_scale: float = 1.0, warmup: int = 100,
                    total: int = 10000, min_frac: float = 0.1):
    """Multiplier for cfg.lr: linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr_scale * warm * cos

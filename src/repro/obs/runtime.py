"""The Observer: one injectable object the whole serving stack reports to.

FAMOUS's evaluation is per-module accounting — latency and GOPS per
attention module, tile-level utilisation — and the serving analogue is a
single seam that surfaces what each layer of the engine is doing:

  * **Runtime** (``ServingEngine``): step phases (prefill-chunk / decode /
    verify) as trace spans + duration histograms, TTFT/TPOT per retired
    request, speculation drafted/accepted, the executable census.
  * **Scheduler**: admissions, queue depth, prefill/decode token counts,
    preemptions.
  * **PageAllocator**: page grow/shrink/free/publish/evict, pool
    utilisation, prefix-cache hits/misses and pages saved.
  * **Drafter** (``PromptLookupDrafter``): lookup hit rate and proposed
    token volume.

Everything is *host-side and pull-based*: hooks take plain python ints
already on the host (the engine's one device→host sync per decode step is
unchanged), counters are dict adds, and reading happens only when someone
calls :meth:`Observer.snapshot` / :meth:`prometheus_text` /
:meth:`trace_json`.  The module is contractually jax-free (lint rule
RA004) so observability can never introduce a device sync.  Measured
overhead of an enabled Observer is ≤2% tok/s on the serving benchmark's
``obs_on`` / ``obs_off`` row pair (gated at 5% in CI; see
docs/observability.md for the catalog and the contract).

``observer=None`` (every constructor's default) resolves to
:data:`NULL_OBSERVER`, whose hooks are empty methods — the off state
costs one no-op call per event.
"""
from __future__ import annotations

import contextlib

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, now

# engine step phases the tracer records (docs/observability.md schema)
PHASES = ("prefill_chunk", "decode", "verify")


class Observer:
    """Metrics + (optional) tracing over one serving engine.

    Construct with ``trace=True`` to also record per-phase trace events;
    metrics are always collected.  One Observer belongs to one engine —
    the census registration and step attribution are per-engine state.
    """

    def __init__(self, trace: bool = False, trace_limit: int = 200_000):
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(limit=trace_limit) if trace else None
        self.step = 0                       # engine step, for attribution
        self._census_source = None
        m = self.metrics
        # -- request lifecycle ----------------------------------------------
        self._enqueued = m.counter(
            "repro_requests_enqueued_total", "requests entering the queues")
        self._admitted = m.counter(
            "repro_requests_admitted_total", "requests bound to a slot")
        self._retired = m.counter(
            "repro_requests_retired_total",
            "requests leaving the engine", ("status",))
        self._ttft = m.histogram(
            "repro_request_ttft_seconds",
            "time from submit to first emitted token")
        self._tpot = m.histogram(
            "repro_request_tpot_seconds",
            "mean per-token time after the first token, per request")
        # -- engine step ----------------------------------------------------
        self._steps = m.counter("repro_engine_steps_total",
                                "scheduler plans executed")
        self._phase_s = m.histogram(
            "repro_step_phase_seconds",
            "host-observed duration of one engine step phase", ("phase",))
        self._queue_depth = m.gauge(
            "repro_queue_depth", "queued requests (pending + resume)")
        self._slots_occ = m.gauge(
            "repro_slots_occupied", "slots holding a request")
        self._tokens = m.counter("repro_tokens_generated_total",
                                 "decode/verify tokens emitted")
        self._prefill_tokens = m.counter(
            "repro_prefill_tokens_total", "prompt tokens prefilled (chunked)")
        self._preempts = m.counter("repro_preemptions_total",
                                   "sequences evicted for re-admission")
        # -- paged pool / prefix cache --------------------------------------
        self._pages = m.counter(
            "repro_pages_total", "page-allocator operations, in pages "
            "(publish counts blocks; evict counts index evictions)", ("op",))
        self._pages_free = m.gauge(
            "repro_pages_free", "allocatable pages (incl. cached-free)")
        self._pages_cached = m.gauge(
            "repro_pages_cached_free", "warm refcount-0 pages on the LRU")
        self._prefix = m.counter(
            "repro_prefix_lookups_total",
            "prefix-cache admission probes", ("result",))
        self._prefix_pages = m.counter(
            "repro_prefix_pages_saved_total",
            "pages aliased from the prefix cache instead of prefilled")
        self._prefix_tokens = m.counter(
            "repro_prefix_tokens_saved_total",
            "prompt tokens whose prefill was skipped by a prefix hit")
        # -- speculation ----------------------------------------------------
        self._spec_steps = m.counter("repro_spec_verify_steps_total",
                                     "verify steps executed")
        self._spec_drafted = m.counter(
            "repro_spec_drafted_total", "draft tokens proposed for verify")
        self._spec_accepted = m.counter(
            "repro_spec_accepted_total",
            "draft tokens accepted (bonus excluded)")
        self._draft_lookups = m.counter(
            "repro_draft_lookups_total", "drafter probes", ("result",))
        self._draft_proposed = m.counter(
            "repro_draft_proposed_tokens_total", "tokens drafters proposed")
        # -- executables ----------------------------------------------------
        self._compilations = m.gauge(
            "repro_engine_compilations",
            "compiled executables per step kind (pull-refreshed from the "
            "engine census)", ("exec",))

    # -- engine hooks --------------------------------------------------------
    def register_census(self, source) -> None:
        """``source()`` -> ``{exec_kind: count}``; re-read at every pull."""
        self._census_source = source

    def census(self) -> dict:
        """Refresh the compilation gauges from the registered source and
        return the census dict (the engine's ``compilations`` property,
        exported).  :func:`repro.analysis.retrace_guard.census` accepts
        an Observer (or its :meth:`snapshot`) directly."""
        if self._census_source is None:
            return {}
        c = {str(k): int(v) for k, v in self._census_source().items()}
        for k, v in c.items():
            self._compilations.set(v, exec=k)
        return c

    def on_step(self, queue_depth: int, occupied: int) -> None:
        self.step += 1
        self._steps.inc()
        self._queue_depth.set(queue_depth)
        self._slots_occ.set(occupied)

    @contextlib.contextmanager
    def phase(self, name: str, **args):
        """Trace span + duration histogram around one step phase."""
        t0 = now()
        if self.tracer is not None:
            self.tracer.begin(name, step=self.step, **args)
        try:
            yield
        finally:
            if self.tracer is not None:
                self.tracer.end(name, step=self.step)
            self._phase_s.observe(now() - t0, phase=name)

    def on_enqueue(self, rid) -> None:
        self._enqueued.inc()

    def on_queue_depth(self, depth: int) -> None:
        self._queue_depth.set(depth)

    def on_admit(self, rid, slot: int, n_tokens: int, cached: int) -> None:
        self._admitted.inc()
        if self.tracer is not None:
            self.tracer.instant("admit", step=self.step, rid=rid, slot=slot,
                                n_tokens=n_tokens, cached=cached)

    def on_prefix_lookup(self, rid, hit_pages: int, hit_tokens: int) -> None:
        self._prefix.inc(result="hit" if hit_pages else "miss")
        if hit_pages:
            self._prefix_pages.inc(hit_pages)
            self._prefix_tokens.inc(hit_tokens)

    def on_prefill_tokens(self, n: int) -> None:
        self._prefill_tokens.inc(n)

    def on_tokens(self, n: int) -> None:
        self._tokens.inc(n)

    def on_preempt(self, rid, slot: int) -> None:
        self._preempts.inc()
        if self.tracer is not None:
            self.tracer.instant("preempt", step=self.step, rid=rid, slot=slot)

    def on_retire(self, req, slot: int = -1) -> None:
        """Request leaving the engine (retired, failed, or swept at
        ``max_steps``): TTFT/TPOT from its clock marks, status counter,
        and the retire trace instant."""
        status = "error" if req.error is not None else "ok"
        self._retired.inc(status=status)
        if req.t_first is not None and req.t_submit is not None:
            self._ttft.observe(req.t_first - req.t_submit)
            if req.t_done is not None and len(req.out) > 1:
                self._tpot.observe((req.t_done - req.t_first)
                                   / (len(req.out) - 1))
        if self.tracer is not None:
            self.tracer.instant("retire", step=self.step, rid=req.rid,
                                slot=slot, n_out=len(req.out), status=status)

    def on_spec_step(self) -> None:
        self._spec_steps.inc()

    def on_draft_verified(self, rid, drafted: int, accepted: int) -> None:
        self._spec_drafted.inc(drafted)
        self._spec_accepted.inc(accepted)

    # -- allocator hooks -----------------------------------------------------
    def on_page_event(self, op: str, slot: int, n: int) -> None:
        if n:
            self._pages.inc(n, op=op)
            if self.tracer is not None:
                self.tracer.instant(f"page_{op}", step=self.step, slot=slot,
                                    pages=n)

    def on_pool(self, free: int, cached_free: int) -> None:
        self._pages_free.set(free)
        self._pages_cached.set(cached_free)

    # -- drafter hooks -------------------------------------------------------
    def on_draft_lookup(self, hit: bool, n_proposed: int) -> None:
        self._draft_lookups.inc(result="hit" if hit else "miss")
        if n_proposed:
            self._draft_proposed.inc(n_proposed)

    # -- pull side -----------------------------------------------------------
    def snapshot(self) -> dict:
        """Flat ``{"name{labels}": value}`` view (census refreshed)."""
        self.census()
        return self.metrics.snapshot()

    def prometheus_text(self) -> str:
        """Text exposition dump (census refreshed first)."""
        self.census()
        return self.metrics.prometheus_text()

    def trace_json(self) -> dict:
        assert self.tracer is not None, "Observer built with trace=False"
        return self.tracer.to_json()

    def write_trace(self, path: str) -> None:
        assert self.tracer is not None, "Observer built with trace=False"
        self.tracer.write(path)


class NullObserver:
    """The off state: every hook is an empty method, ``phase`` yields a
    shared no-op context.  Engines call hooks unconditionally; this keeps
    the disabled cost at one attribute lookup + no-op call per event."""

    tracer = None
    step = 0
    _NULL_CTX = contextlib.nullcontext()

    def phase(self, name: str, **args):
        return self._NULL_CTX

    def register_census(self, source) -> None: pass
    def census(self) -> dict: return {}
    def on_step(self, queue_depth: int, occupied: int) -> None: pass
    def on_enqueue(self, rid) -> None: pass
    def on_queue_depth(self, depth: int) -> None: pass
    def on_admit(self, rid, slot, n_tokens, cached) -> None: pass
    def on_prefix_lookup(self, rid, hit_pages, hit_tokens) -> None: pass
    def on_prefill_tokens(self, n) -> None: pass
    def on_tokens(self, n) -> None: pass
    def on_preempt(self, rid, slot) -> None: pass
    def on_retire(self, req, slot=-1) -> None: pass
    def on_spec_step(self) -> None: pass
    def on_draft_verified(self, rid, drafted, accepted) -> None: pass
    def on_page_event(self, op, slot, n) -> None: pass
    def on_pool(self, free, cached_free) -> None: pass
    def on_draft_lookup(self, hit, n_proposed) -> None: pass


NULL_OBSERVER = NullObserver()

"""Host-pure observability for the serving stack: metrics, tracing, and
the injectable :class:`~repro.obs.runtime.Observer` the engine reports to.

Three pieces (see docs/observability.md):

  * :mod:`repro.obs.metrics` — a low-overhead registry of counters /
    gauges / histograms (fixed log-spaced buckets) with a Prometheus
    text-exposition renderer and format validator.
  * :mod:`repro.obs.trace` — a structured per-step-phase event tracer
    exporting Chrome/Perfetto ``trace_event`` JSON, and the repo's single
    monotonic clock source (:func:`repro.obs.trace.now`).
  * :mod:`repro.obs.runtime` — the :class:`Observer` seam wired through
    ``ServingEngine`` / ``Scheduler`` / ``PageAllocator`` /
    ``PromptLookupDrafter``, plus the zero-cost :data:`NULL_OBSERVER`
    default.

Like the Scheduler, every module here is contractually jax-free (lint
rule RA004, ``repro.analysis.lint.PURE_MODULES``): observability can
never add a device sync or an executable to the hot loop.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               validate_prometheus_text)
from repro.obs.runtime import NULL_OBSERVER, NullObserver, Observer
from repro.obs.trace import Tracer, now

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "validate_prometheus_text",
    "Tracer", "now",
    "Observer", "NullObserver", "NULL_OBSERVER",
]

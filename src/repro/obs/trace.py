"""Structured step tracing: one event per engine step phase, exported as
Chrome/Perfetto ``trace_event`` JSON.

The tracer records what the scheduler/runtime split actually *does* each
step — admit, prefill-chunk, decode, verify, preempt, retire, and the
allocator's page grow/shrink/publish/evict — each event carrying its
slot / request-id / step attribution in ``args``.  Phases are duration
pairs (``ph: "B"`` / ``"E"``), bookkeeping moments are instants
(``ph: "i"``), and the export is the ``{"traceEvents": [...]}`` JSON
object both ``chrome://tracing`` and https://ui.perfetto.dev load
directly.

This module also owns the repo's **single monotonic clock source**:
:func:`now` is the only ``time.perf_counter`` call site the serving
stack uses.  ``Request.t_submit`` / ``t_first`` / ``t_done`` and every
trace timestamp come from this one clock, so TTFT/TPOT computed from
request marks, trace durations, and benchmark timings can never disagree
about what "a millisecond" was.

Host-pure by contract (lint rule RA004): recording an event is a dict
append — no numpy, no jax, no device syncs.  The buffer is bounded
(``limit``); overflow drops *new* events and counts them in
``dropped`` rather than growing without bound under a long run.
"""
from __future__ import annotations

import json
import time


def now() -> float:
    """The serving stack's one monotonic clock (seconds, float).

    Every wall-clock mark — request TTFT/TPOT fields, trace event
    timestamps, benchmark timing loops — reads this function, so there
    is exactly one ``time.perf_counter`` call site to reason about.
    """
    return time.perf_counter()


class Tracer:
    """Bounded in-memory trace_event recorder.

    Events use the Trace Event Format's JSON array flavour: ``ts`` is
    microseconds relative to tracer construction, ``pid`` is always 0,
    and ``tid`` defaults to 0 (engine phases are sequential on the host
    thread, so B/E pairs nest trivially).
    """

    def __init__(self, limit: int = 200_000):
        self.t0 = now()
        self.limit = limit
        self.events: list = []
        self.dropped = 0
        self._open = 0     # currently-open B events (for balance checks)

    def _ts(self) -> float:
        return (now() - self.t0) * 1e6

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.limit:
            self.dropped += 1
            return
        self.events.append(ev)

    def begin(self, name: str, **args) -> None:
        """Open a duration event (phase start)."""
        self._open += 1
        self._emit({"name": name, "ph": "B", "ts": self._ts(),
                    "pid": 0, "tid": 0, "args": args})

    def end(self, name: str, **args) -> None:
        """Close the most recent open duration event of ``name``."""
        self._open -= 1
        self._emit({"name": name, "ph": "E", "ts": self._ts(),
                    "pid": 0, "tid": 0, "args": args})

    def instant(self, name: str, **args) -> None:
        """A zero-duration bookkeeping moment (admit, retire, page op)."""
        self._emit({"name": name, "ph": "i", "ts": self._ts(),
                    "pid": 0, "tid": 0, "s": "t", "args": args})

    @property
    def balanced(self) -> bool:
        """True when every begun phase has been ended."""
        return self._open == 0

    def to_json(self) -> dict:
        """The Chrome/Perfetto trace object (JSON-serialisable)."""
        meta = {"clock": "time.perf_counter", "t0": self.t0,
                "dropped": self.dropped}
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": meta}

    def write(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_json(), f)

"""Low-overhead metrics registry: counters, gauges, log-bucket histograms.

The serving hot loop runs at ~millisecond step granularity, so the
instruments here are built for cheap host-side updates: a counter
increment is one dict lookup plus a float add, a histogram observation is
one ``bisect`` into a *fixed* tuple of log-spaced bucket bounds (no numpy,
no allocation, no device anything — the module is contractually jax-free,
lint rule RA004).  Reading is pull-based: :meth:`MetricsRegistry.collect`
/ :meth:`snapshot` walk the instruments on demand, and
:meth:`prometheus_text` renders the standard text exposition format
(``# HELP`` / ``# TYPE`` / escaped labels / cumulative ``_bucket`` lines)
that the async front-end will eventually serve from ``/metrics``.

Histograms use fixed log-spaced buckets (default ``LOG_BUCKETS``:
20 buckets per decade over 1e-5..1e5, ~12% relative resolution) so any
two histograms of the same schema are mergeable and a quantile is
reconstructible from the bucket counts alone —
:meth:`Histogram.quantile` does the same linear-within-bucket
interpolation as PromQL's ``histogram_quantile``.  The serving benchmark
reports its TTFT/TPOT percentiles through this exact class
(:meth:`Histogram.of`), so bench rows and live metrics can never
disagree about what a percentile means.

:func:`validate_prometheus_text` is the golden-format checker used by the
tests and the CI observability stage: it re-parses an exposition dump and
verifies sample syntax, label escaping, ``TYPE`` declarations, and
histogram invariants (cumulative buckets, ``+Inf`` == ``_count``).
"""
from __future__ import annotations

import math
import re
from bisect import bisect_left


def log_buckets(lo: float = 1e-5, hi: float = 1e5,
                per_decade: int = 20) -> tuple:
    """Fixed log-spaced bucket upper bounds covering ``[lo, hi]``."""
    assert 0 < lo < hi and per_decade >= 1
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


LOG_BUCKETS = log_buckets()


def _escape(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return format(v, ".9g")


_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*$")


class _Instrument:
    """Shared label plumbing: values live in ``_data[label_values]``."""

    kind = "untyped"

    def __init__(self, name: str, help: str, label_names: tuple = ()):
        assert _NAME_RE.match(name), name
        assert all(_LABEL_RE.match(l) for l in label_names), label_names
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._data: dict = {}

    def _key(self, labels: dict) -> tuple:
        if not self.label_names:
            assert not labels, (self.name, labels)
            return ()
        return tuple(str(labels[l]) for l in self.label_names)

    def _label_str(self, key: tuple, extra: tuple = ()) -> str:
        pairs = [f'{l}="{_escape(v)}"'
                 for l, v in tuple(zip(self.label_names, key)) + extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""

    def label_keys(self) -> list:
        return sorted(self._data)


class Counter(_Instrument):
    """Monotonically increasing count (resets only with the registry)."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        key = self._key(labels)
        self._data[key] = self._data.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return self._data.get(self._key(labels), 0.0)

    def samples(self):
        for key in sorted(self._data):
            yield self.name, self._label_str(key), self._data[key]


class Gauge(_Instrument):
    """A value that goes up and down (queue depth, free pages, ...)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._data[self._key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = self._key(labels)
        self._data[key] = self._data.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return self._data.get(self._key(labels), 0.0)

    samples = Counter.samples


class Histogram(_Instrument):
    """Fixed-bucket histogram; ``observe`` is one bisect, no allocation.

    ``buckets`` are *upper bounds* (an implicit ``+Inf`` bucket is always
    appended).  The default log-spaced schema trades ~12% relative
    quantile resolution for mergeability and O(1) hot-path cost.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, label_names: tuple = (),
                 buckets: tuple = LOG_BUCKETS):
        super().__init__(name, help, label_names)
        assert buckets and tuple(buckets) == tuple(sorted(buckets))
        self.buckets = tuple(float(b) for b in buckets)

    def _cell(self, labels: dict) -> list:
        key = self._key(labels)
        cell = self._data.get(key)
        if cell is None:
            # [counts per bucket ..., +Inf count, sum]
            cell = self._data[key] = [0] * (len(self.buckets) + 1) + [0.0]
        return cell

    def observe(self, value: float, **labels) -> None:
        cell = self._cell(labels)
        cell[bisect_left(self.buckets, value)] += 1
        cell[-1] += value

    def count(self, **labels) -> int:
        cell = self._data.get(self._key(labels))
        return sum(cell[:-1]) if cell else 0

    def sum(self, **labels) -> float:
        cell = self._data.get(self._key(labels))
        return cell[-1] if cell else 0.0

    def quantile(self, q: float, **labels) -> float:
        """PromQL ``histogram_quantile`` semantics: find the bucket the
        q-th observation falls in and interpolate linearly inside it
        (values in the ``+Inf`` bucket clamp to the highest finite
        bound; an empty histogram returns NaN)."""
        assert 0.0 <= q <= 1.0, q
        cell = self._data.get(self._key(labels))
        if not cell:
            return math.nan
        total = sum(cell[:-1])
        if total == 0:
            return math.nan
        rank = q * total
        cum = 0
        for i, n in enumerate(cell[:-2]):
            prev, cum = cum, cum + n
            if cum >= rank and n:
                lo = self.buckets[i - 1] if i else 0.0
                hi = self.buckets[i]
                return lo + (hi - lo) * ((rank - prev) / n)
        return self.buckets[-1]   # +Inf bucket: clamp to the last bound

    def percentile(self, p: float, **labels) -> float:
        return self.quantile(p / 100.0, **labels)

    @classmethod
    def of(cls, values, buckets: tuple = LOG_BUCKETS) -> "Histogram":
        """Standalone histogram over ``values`` — the shared percentile
        implementation benchmarks use, so offline rows and live metrics
        agree by construction."""
        h = cls("adhoc", "ad-hoc value summary", buckets=buckets)
        for v in values:
            h.observe(v)
        return h

    def samples(self):
        for key in sorted(self._data):
            cell = self._data[key]
            cum = 0
            for i, bound in enumerate(self.buckets):
                cum += cell[i]
                yield (self.name + "_bucket",
                       self._label_str(key, (("le", _fmt(bound)),)), cum)
            cum += cell[len(self.buckets)]
            yield (self.name + "_bucket",
                   self._label_str(key, (("le", "+Inf"),)), cum)
            yield self.name + "_sum", self._label_str(key), cell[-1]
            yield self.name + "_count", self._label_str(key), cum


class MetricsRegistry:
    """Name-keyed instrument registry with a text-exposition renderer."""

    def __init__(self):
        self._metrics: dict = {}

    def _register(self, cls, name, help, label_names, **kw):
        m = self._metrics.get(name)
        if m is not None:
            assert type(m) is cls and m.label_names == tuple(label_names), \
                f"metric {name!r} re-registered with a different schema"
            return m
        m = self._metrics[name] = cls(name, help, tuple(label_names), **kw)
        return m

    def counter(self, name: str, help: str, label_names=()) -> Counter:
        return self._register(Counter, name, help, label_names)

    def gauge(self, name: str, help: str, label_names=()) -> Gauge:
        return self._register(Gauge, name, help, label_names)

    def histogram(self, name: str, help: str, label_names=(),
                  buckets: tuple = LOG_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, label_names,
                              buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def collect(self):
        """Yield ``(sample_name, label_str, value)`` for every sample."""
        for name in sorted(self._metrics):
            yield from self._metrics[name].samples()

    def snapshot(self) -> dict:
        """Flat pull-based view ``{"name{labels}": value}`` — the census
        source :func:`repro.analysis.retrace_guard.census` understands."""
        return {name + labels: value for name, labels, value in self.collect()}

    def prometheus_text(self) -> str:
        out = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out.append(f"# HELP {m.name} {_escape(m.help)}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for sname, labels, value in m.samples():
                out.append(f"{sname}{labels} {_fmt(value)}")
        return "\n".join(out) + "\n"


# --------------------------------------------------------------------------
# exposition-format validation (the golden checker for tests and CI)
# --------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r" (?P<value>[^ ]+)$")
_LABEL_PAIR_RE = re.compile(
    r'\s*(?P<label>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<value>(?:[^"\\]|\\.)*)"\s*'
    r"(?:,|$)")


def _parse_value(text: str) -> float:
    if text == "+Inf":
        return math.inf
    if text == "-Inf":
        return -math.inf
    return float(text)   # ValueError propagates to the caller


def _parse_labels(text: str) -> dict:
    labels, pos = {}, 0
    while pos < len(text):
        m = _LABEL_PAIR_RE.match(text, pos)
        if m is None:
            raise ValueError(f"malformed label pair at {text[pos:]!r}")
        labels[m.group("label")] = m.group("value")
        pos = m.end()
    return labels


def validate_prometheus_text(text: str) -> int:
    """Validate a text-exposition dump; returns the number of samples.

    Checks: sample-line syntax, metric/label name charsets, parseable
    (escaped) label values, every sample preceded by a ``# TYPE`` line of
    a known type, and histogram structure — cumulative non-decreasing
    ``_bucket`` counts per label set, a ``+Inf`` bucket equal to
    ``_count``.  Raises :class:`ValueError` on the first violation.
    """
    types: dict = {}
    hist: dict = {}   # (base name, frozen non-le labels) -> [(le, cum)]
    hist_count: dict = {}
    n_samples = 0
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) < 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: bad TYPE line {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name = m.group("name")
        labels = _parse_labels(m.group("labels") or "")
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            raise ValueError(f"line {lineno}: bad value {m.group('value')!r}")
        n_samples += 1
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        declared = types.get(name) or types.get(base)
        if declared is None:
            raise ValueError(f"line {lineno}: sample {name!r} has no "
                             f"preceding # TYPE line")
        if declared == "histogram" and name.endswith("_bucket"):
            if "le" not in labels:
                raise ValueError(f"line {lineno}: histogram bucket without "
                                 f"an le label")
            key = (base, frozenset((k, v) for k, v in labels.items()
                                   if k != "le"))
            hist.setdefault(key, []).append(
                (_parse_value(labels["le"]), value))
        elif declared == "histogram" and name.endswith("_count"):
            hist_count[(base, frozenset(labels.items()))] = value
    for (base, labelset), buckets in hist.items():
        les = [le for le, _ in buckets]
        cums = [c for _, c in buckets]
        if les != sorted(les):
            raise ValueError(f"{base}: bucket le bounds not sorted")
        if cums != sorted(cums):
            raise ValueError(f"{base}: bucket counts not cumulative")
        if not les or les[-1] != math.inf:
            raise ValueError(f"{base}: missing +Inf bucket")
        count = hist_count.get((base, labelset))
        if count is not None and count != cums[-1]:
            raise ValueError(f"{base}: _count {count} != +Inf bucket "
                             f"{cums[-1]}")
    return n_samples

"""Deterministic synthetic data pipeline.

Production posture: the pipeline is *host-sharded* — each host materialises
only its slice of the global batch (``make_global_batch`` uses
``jax.make_array_from_callback`` so a 1000-host job never builds the global
array anywhere), is *stateless* (batch = f(seed, step), so restart/elastic
resize never replays or skips data), and supports prefetch depth for
overlapping host data work with device steps.
"""
from __future__ import annotations

import dataclasses
import threading
import queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import frontends


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    prefetch: int = 2


def _tokens_for(cfg: ModelConfig, seed: int, step: int, lo: int, hi: int,
                seq_len: int) -> np.ndarray:
    """Rows [lo, hi) of the global batch for ``step`` — pure per-row function
    (row r depends only on (seed, step, r), so any host can build any slice
    and slices compose exactly)."""
    v = cfg.vocab_size
    out = np.empty((hi - lo, seq_len + 1), np.int32)
    for i, row in enumerate(range(lo, hi)):
        rng = np.random.Generator(np.random.Philox(
            key=[(seed << 32) ^ step, row]))
        # a Zipfian-ish unigram mix makes loss curves non-degenerate
        z = rng.zipf(1.3, size=seq_len + 1).astype(np.int64)
        out[i] = (z % v).astype(np.int32)
    return out


def host_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int, step: int,
               lo: int = 0, hi: Optional[int] = None) -> dict:
    """Build rows [lo, hi) of step's global batch on this host."""
    hi = shape.global_batch if hi is None else hi
    toks = _tokens_for(cfg, seed, step, lo, hi, shape.seq_len)
    batch = {"targets": toks[:, 1:]}
    if cfg.frontend:
        emb = np.empty((hi - lo, shape.seq_len, cfg.d_model), np.float32)
        for i, row in enumerate(range(lo, hi)):
            rng = np.random.Generator(np.random.Philox(
                key=[(seed << 32) ^ step ^ 0x5EED, row]))
            emb[i] = 0.02 * rng.standard_normal(
                (shape.seq_len, cfg.d_model)).astype(np.float32)
        batch["inputs"] = emb
    else:
        batch["inputs"] = toks[:, :-1]
    return batch


def make_global_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int,
                      step: int, sharding) -> dict:
    """Build a jax.Array global batch where each device's shard is produced
    locally from the deterministic generator (no global materialisation)."""

    def build(name, full_shape, dtype):
        def cb(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else full_shape[0]
            b = host_batch(cfg, shape, seed, step, lo, hi)[name]
            rest = tuple(index[1:])
            return b[(slice(None),) + rest].astype(dtype)

        return jax.make_array_from_callback(full_shape, sharding, cb)

    B, S = shape.global_batch, shape.seq_len
    out = {"targets": build("targets", (B, S), jnp.int32)}
    if cfg.frontend:
        out["inputs"] = build("inputs", (B, S, cfg.d_model), jnp.float32)
    else:
        out["inputs"] = build("inputs", (B, S), jnp.int32)
    return out


class PrefetchIterator:
    """Background-thread prefetch of host batches (overlap data & compute)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig, sharding, start_step: int = 0):
        self._q: queue.Queue = queue.Queue(maxsize=data_cfg.prefetch)
        self._stop = threading.Event()
        self._args = (cfg, shape, data_cfg.seed)
        self._sharding = sharding
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        cfg, shape, seed = self._args
        step = self._step
        while not self._stop.is_set():
            batch = make_global_batch(cfg, shape, seed, step, self._sharding)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()

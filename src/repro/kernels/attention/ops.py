"""Jitted public wrapper for the fused MHA kernel.

Layout adaptation: model code uses (B, S, H, dh); the kernel uses flattened
(B·H, S, dh).  Backward: flash custom-VJP from the FAMOUS core (blockwise
recompute) — on TPU the forward runs this kernel; the backward runs the XLA
flash path (a dedicated bwd kernel is a further optimisation documented in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention import mha as mha_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _to_flat(x):  # (B, S, H, dh) -> (B*H, S, dh)
    B, S, H, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)


def _from_flat(x, B, H):  # (B*H, S, dh) -> (B, S, H, dh)
    BH, S, dh = x.shape
    return x.reshape(B, H, S, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_offset", "block_q", "block_k",
    "interpret"))
def mha(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
        block_q=512, block_k=512, interpret=None):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh). Returns (B, Sq, H, dh)."""
    B, Sq, H, dh = q.shape
    interpret = _interpret_default() if interpret is None else interpret
    out = mha_kernel.mha_forward(
        _to_flat(q), _to_flat(k), _to_flat(v), causal=causal, window=window,
        scale=scale, q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return _from_flat(out, B, H)

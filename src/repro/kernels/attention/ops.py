"""Jitted public wrapper for the fused MHA kernel.

Layout adaptation: model code uses (B, S, H, dh); the kernel uses flattened
(B·H, S, dh).  Backward: a flash custom-VJP whose forward *and* backward run
Pallas kernels — the forward additionally emits the per-row LSE, and the
backward recomputes P tile-by-tile in the dq / dk-dv kernels
(kernels/attention/mha.py), so ``impl="pallas"`` trains end-to-end with no
fallback to the XLA flash path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.attention import mha as mha_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _to_flat(x):  # (B, S, H, dh) -> (B*H, S, dh)
    B, S, H, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, dh)


def _from_flat(x, B, H):  # (B*H, S, dh) -> (B, S, H, dh)
    BH, S, dh = x.shape
    return x.reshape(B, H, S, dh).transpose(0, 2, 1, 3)


# --- flash custom VJP over the flattened (BH, S, dh) layout ----------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_mha(q, k, v, causal, window, scale, q_offset, block_q, block_k,
               interpret):
    return mha_kernel.mha_forward(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)


def _flash_mha_fwd(q, k, v, causal, window, scale, q_offset, block_q,
                   block_k, interpret):
    out, lse = mha_kernel.mha_forward(
        q, k, v, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret, return_lse=True)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(causal, window, scale, q_offset, block_q, block_k,
                   interpret, res, dout):
    q, k, v, out, lse = res
    dq, dk, dv = mha_kernel.mha_backward(
        q, k, v, out, lse, dout, causal=causal, window=window, scale=scale,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "q_offset", "block_q", "block_k",
    "interpret"))
def mha(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
        block_q=512, block_k=512, interpret=None):
    """q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh). Returns (B, Sq, H, dh).

    Differentiable: gradients flow through the flash backward Pallas
    kernels (custom VJP), with the GQA head-group reduction applied to
    dk/dv inside the kernel wrapper."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    interpret = _interpret_default() if interpret is None else interpret
    # resolve data-independent knobs here so the custom-VJP nondiff args are
    # concrete (the backward kernels reuse the exact forward tiling + scale)
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    out = _flash_mha(_to_flat(q), _to_flat(k), _to_flat(v), causal, window,
                     scale, q_offset, block_q, block_k, interpret)
    return _from_flat(out, B, H)

"""Pure-jnp oracle for the fused MHA kernel (paper Algorithms 2 & 3)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None, q_offset: int = 0):
    """q: (BH, Sq, dh); k, v: (BKV, Skv, dh), BH = BKV * group.
    Materialised-S softmax attention — the QK_PM/softmax/SV_PM oracle."""
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Fused MHA forward Pallas TPU kernel — FAMOUS QK_PM → softmax → SV_PM in
one pass over key tiles.

Mapping from the paper (DESIGN.md §2): the (block_q, block_k) tile pair is
the TS analogue; Q tiles stay resident in VMEM (the Q BRAM), K/V tiles
stream through (the K/V BRAMs being reloaded per iteration), the MXU plays
the PE array and the VPU the LUT-based softmax.  Unlike the FPGA (SL=64),
S is never materialised: an online (running max/sum) softmax accumulates
into a VMEM scratch accumulator across the key-tile grid dimension.

Grid: (B·H, Sq/block_q, Skv/block_k) — the last dimension is sequential
("arbitrary"), carrying (acc, m, l) scratch across key tiles; batch·head and
query tiles are parallel.  GQA is handled in the K/V index maps (q head h
reads kv head h // group), mirroring FAMOUS's shared-K-BRAM PE groups.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, window: int, block_q: int,
                block_k: int, num_k_blocks: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                  # (bk, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def mha_forward(q, k, v, *, causal: bool = True, window: int = 0,
                scale: float | None = None, q_offset: int = 0,
                block_q: int = 512, block_k: int = 512,
                interpret: bool = False):
    """q: (BH, Sq, dh); k, v: (BKV, Skv, dh) with BH = BKV * group.
    Returns (BH, Sq, dh)."""
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    grid = (BH, nq, nk)

    kernel = functools.partial(
        _mha_kernel, scale=float(scale), causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, group=group: (bh // group, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, group=group: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum l
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)

"""Fused MHA Pallas TPU kernels — FAMOUS QK_PM → softmax → SV_PM in one
pass over key tiles, plus flash backward kernels (dq and dk/dv).

Mapping from the paper (DESIGN.md §2): the (block_q, block_k) tile pair is
the TS analogue; Q tiles stay resident in VMEM (the Q BRAM), K/V tiles
stream through (the K/V BRAMs being reloaded per iteration), the MXU plays
the PE array and the VPU the LUT-based softmax.  Unlike the FPGA (SL=64),
S is never materialised: an online (running max/sum) softmax accumulates
into a VMEM scratch accumulator across the key-tile grid dimension.

Grid: (B·H, Sq/block_q, Skv/block_k) — the last dimension is sequential
("arbitrary"), carrying (acc, m, l) scratch across key tiles; batch·head and
query tiles are parallel.  GQA is handled in the K/V index maps (q head h
reads kv head h // group), mirroring FAMOUS's shared-K-BRAM PE groups.

Backward (FlashAttention-style blockwise recompute, mirroring the XLA
``_flash_bwd_rule`` in core/famous.py): the forward additionally emits the
per-row log-sum-exp (LSE); the backward never stores S or P but recomputes
the (block_q, block_k) probability tile from Q, K and the saved LSE.  Two
kernels:

* ``_mha_bwd_dq_kernel``  — grid (B·H, Sq/block_q, Skv/block_k), key tiles
  sequential, accumulating dq for one query tile in VMEM scratch.
* ``_mha_bwd_dkv_kernel`` — grid (B·H, Skv/block_k, Sq/block_q), query
  tiles sequential, accumulating dk and dv for one key tile in VMEM
  scratch.  GQA: gradients are produced per *query* head; the wrapper
  reduces over the head group to recover the shared-KV-head gradient
  (the adjoint of the shared-K-BRAM broadcast).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc

NEG_INF = -1e30


def _tile_mask(s_shape, iq, ik, *, causal: bool, window: int, block_q: int,
               block_k: int, q_offset: int):
    """Boolean validity mask for one (block_q, block_k) score tile."""
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, s_shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s_shape, 1)
    ok = jnp.ones(s_shape, dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    return ok


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _mha_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, window: int, block_q: int,
                block_k: int, num_k_blocks: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                  # (bk, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    ok = _tile_mask(s.shape, iq, ik, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, q_offset=q_offset)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, :] = (m_ref[...] + jnp.log(l))[:, 0]


def mha_forward(q, k, v, *, causal: bool = True, window: int = 0,
                scale: float | None = None, q_offset: int = 0,
                block_q: int = 512, block_k: int = 512,
                interpret: bool = False, return_lse: bool = False):
    """q: (BH, Sq, dh); k, v: (BKV, Skv, dh) with BH = BKV * group.
    Returns (BH, Sq, dh), plus the f32 row log-sum-exp (BH, Sq) when
    ``return_lse`` (the flash backward residual)."""
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    grid = (BH, nq, nk)

    kernel = functools.partial(
        _mha_kernel, scale=float(scale), causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk, q_offset=q_offset)

    out, lse = pc.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, group=group: (bh // group, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, group=group: (bh // group, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_q), lambda bh, iq, ik: (bh, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pc.VMEM((block_q, dh), jnp.float32),   # acc
            pc.VMEM((block_q, 1), jnp.float32),    # running max m
            pc.VMEM((block_q, 1), jnp.float32),    # running sum l
        ],
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(q, k, v)
    return (out, lse) if return_lse else out


# ---------------------------------------------------------------------------
# backward — dq (key tiles sequential)
# ---------------------------------------------------------------------------

def _mha_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dq_ref, dq_acc, *, scale: float, causal: bool,
                       window: int, block_q: int, block_k: int,
                       num_k_blocks: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                # (bq, dh)
    lse = lse_ref[0][:, None]                         # (bq, 1)
    delta = delta_ref[0][:, None]                     # (bq, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _tile_mask(s.shape, iq, ik, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, q_offset=q_offset)
    p = jnp.where(ok, jnp.exp(s - lse), 0.0)          # recomputed P tile
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    dq_acc[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == num_k_blocks - 1)
    def _flush():
        dq_ref[0, ...] = dq_acc[...] * scale


# ---------------------------------------------------------------------------
# backward — dk/dv (query tiles sequential)
# ---------------------------------------------------------------------------

def _mha_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                        dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                        causal: bool, window: int, block_q: int,
                        block_k: int, num_q_blocks: int, q_offset: int):
    ik = pl.program_id(1)
    iq = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)                  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)                # (bq, dh)
    lse = lse_ref[0][:, None]                         # (bq, 1)
    delta = delta_ref[0][:, None]                     # (bq, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _tile_mask(s.shape, iq, ik, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, q_offset=q_offset)
    p = jnp.where(ok, jnp.exp(s - lse), 0.0)          # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)                             # (bq, bk)
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(iq == num_q_blocks - 1)
    def _flush():
        dk_ref[0, ...] = dk_acc[...] * scale
        dv_ref[0, ...] = dv_acc[...]


def mha_backward(q, k, v, out, lse, dout, *, causal: bool = True,
                 window: int = 0, scale: float | None = None,
                 q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                 interpret: bool = False):
    """Flash backward.  q/dout/out: (BH, Sq, dh); k, v: (BKV, Skv, dh);
    lse: (BH, Sq) f32.  Returns f32 (dq (BH, Sq, dh), dk, dv (BKV, Skv, dh))
    with the GQA head-group reduction already applied."""
    BH, Sq, dh = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k

    # D_i = Σ_d dO_i·O_i — the softmax-normalisation correction, computed
    # once outside the kernels (cheap elementwise; one pass over O/dO).
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                    # (BH, Sq)
    lse = lse.astype(jnp.float32)

    common = dict(scale=float(scale), causal=causal, window=window,
                  block_q=block_q, block_k=block_k, q_offset=q_offset)

    q_spec = pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, block_k, dh), lambda bh, iq, ik, group=group: (bh // group, ik, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda bh, iq, ik: (bh, iq))

    dq = pc.pallas_call(
        functools.partial(_mha_bwd_dq_kernel, num_k_blocks=nk, **common),
        grid=(BH, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), jnp.float32),
        scratch_shapes=[pc.VMEM((block_q, dh), jnp.float32)],
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # dk/dv grid transposes the tile loops: (bh, ik, iq), query sequential.
    q_spec_t = pl.BlockSpec((1, block_q, dh), lambda bh, ik, iq: (bh, iq, 0))
    kv_spec_t = pl.BlockSpec(
        (1, block_k, dh), lambda bh, ik, iq, group=group: (bh // group, ik, 0))
    row_spec_t = pl.BlockSpec((1, block_q), lambda bh, ik, iq: (bh, iq))
    dkv_spec = pl.BlockSpec((1, block_k, dh), lambda bh, ik, iq: (bh, ik, 0))

    dk, dv = pc.pallas_call(
        functools.partial(_mha_bwd_dkv_kernel, num_q_blocks=nq, **common),
        grid=(BH, nk, nq),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[dkv_spec, dkv_spec],
        out_shape=[jax.ShapeDtypeStruct((BH, Skv, dh), jnp.float32),
                   jax.ShapeDtypeStruct((BH, Skv, dh), jnp.float32)],
        scratch_shapes=[pc.VMEM((block_k, dh), jnp.float32),
                        pc.VMEM((block_k, dh), jnp.float32)],
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    if group > 1:
        # adjoint of the shared-KV-head broadcast: sum over the head group
        dk = dk.reshape(BKV, group, Skv, dh).sum(axis=1)
        dv = dv.reshape(BKV, group, Skv, dh).sum(axis=1)
    return dq, dk, dv

"""Jitted wrappers for the recurrence kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scan import linear_scan


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_r", "block_s", "interpret"))
def rglru(a, b, *, block_r=512, block_s=256, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return linear_scan.rglru_scan(a, b, block_r=block_r, block_s=block_s,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk=64, interpret=None):
    """r,k,v,logw: (B, H, S, dh); u: (H, dh). Returns (B, H, S, dh) f32."""
    B, H, S, dh = r.shape
    interpret = _interpret_default() if interpret is None else interpret
    flat = lambda x: x.reshape(B * H, S, dh)
    uu = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh)
    out = linear_scan.wkv6_scan(flat(r), flat(k), flat(v), flat(logw), uu,
                                chunk=chunk, interpret=interpret)
    return out.reshape(B, H, S, dh)

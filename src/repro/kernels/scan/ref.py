"""Pure-jnp oracles for the linear-recurrence kernels: straight sequential
scans (no chunking, no log-space tricks) — the ground truth."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_reference(a, b):
    """h_t = a_t h_{t-1} + b_t ; a, b: (B, S, R) -> (B, S, R) f32."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    a = a.astype(jnp.float32).swapaxes(0, 1)
    b = b.astype(jnp.float32).swapaxes(0, 1)
    h0 = jnp.zeros_like(a[0])
    _, hs = jax.lax.scan(step, h0, (a, b))
    return hs.swapaxes(0, 1)


def wkv6_reference(r, k, v, logw, u):
    """Sequential wkv6. r,k,v,logw: (BH, S, dh); u: (BH, dh) -> (BH,S,dh)."""
    rf, kf, vf, wf = (x.astype(jnp.float32).swapaxes(0, 1)
                      for x in (r, k, v, logw))
    uf = u.astype(jnp.float32)

    def step(s, rkvw):
        r_t, k_t, v_t, w_t = rkvw            # (BH, dh)
        kv = jnp.einsum("bd,be->bde", k_t, v_t)
        out = jnp.einsum("bd,bde->be", r_t, s + uf[..., None] * kv)
        s = jnp.exp(w_t)[..., None] * s + kv
        return s, out

    BH, dh = rf.shape[1], rf.shape[2]
    s0 = jnp.zeros((BH, dh, dh), jnp.float32)
    _, outs = jax.lax.scan(step, s0, (rf, kf, vf, wf))
    return outs.swapaxes(0, 1)

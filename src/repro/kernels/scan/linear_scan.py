"""Linear-recurrence Pallas kernels (RG-LRU and RWKV-6 wkv).

RG-LRU: h_t = a_t ⊙ h_{t-1} + b_t, elementwise in the feature dim.  Grid
(B, R/block_r, S/block_s): time is sequential ("arbitrary"), the running
state h lives in VMEM scratch across time tiles, and the time loop *within*
a tile is a fori_loop over rows already resident in VMEM — the TPU-native
reshaping of a recurrence that a GPU implementation would assign one thread
per feature.  (batch, feature) tiles are parallel.

wkv6: S_t = diag(w_t) S_{t-1} + k_t v_tᵀ; out_t = r_t (S_{t-1} + diag(u) k_t
v_tᵀ).  Chunked parallel form (flash-linear-attention): within a chunk of C
timesteps everything is dense matmuls with cumulative log-decay masks (MXU
work); the (dh × dh) state crosses chunks in VMEM scratch.  Grid (B·H,
S/C) with the chunk dim sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)       # (block_s, block_r)
    b = b_ref[0].astype(jnp.float32)

    def body(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_s, body, h_ref[...])


def rglru_scan(a, b, *, block_r: int = 512, block_s: int = 256,
               interpret: bool = False):
    """a, b: (B, S, R) -> h: (B, S, R) f32 with h_t = a_t h_{t-1} + b_t."""
    B, S, R = a.shape
    block_r = min(block_r, R)
    block_s = min(block_s, S)
    assert S % block_s == 0 and R % block_r == 0
    grid = (B, R // block_r, S // block_s)
    return pc.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_r), lambda b_, jr, it: (b_, it, jr)),
            pl.BlockSpec((1, block_s, block_r), lambda b_, jr, it: (b_, it, jr)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_r),
                               lambda b_, jr, it: (b_, it, jr)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), jnp.float32),
        scratch_shapes=[pc.VMEM((block_r,), jnp.float32)],
        compiler_params=pc.compiler_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# RWKV6 wkv — chunked
# ---------------------------------------------------------------------------

def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                 chunk: int, n_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rb = r_ref[0].astype(jnp.float32)      # (C, dh)
    kb = k_ref[0].astype(jnp.float32)
    vb = v_ref[0].astype(jnp.float32)
    wb = w_ref[0].astype(jnp.float32)      # log-decay <= 0
    u = u_ref[0].astype(jnp.float32)       # (1, dh) bonus

    cw = jnp.cumsum(wb, axis=0)            # inclusive logW_t
    cw_prev = cw - wb
    s = s_ref[...]                         # (dh, dh)

    inter = jax.lax.dot_general(rb * jnp.exp(cw_prev), s,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    qexp = rb * jnp.exp(cw_prev)
    kexp = kb * jnp.exp(-cw)
    att = jax.lax.dot_general(qexp, kexp, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(si < ti, att, 0.0)     # strict lower triangle
    diag = jnp.sum(rb * u * kb, axis=1, keepdims=True)  # (C, 1)
    intra = jax.lax.dot_general(att, vb, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra = intra + diag * vb
    o_ref[0, ...] = (inter + intra).astype(o_ref.dtype)

    w_tail = jnp.exp(cw[-1:, :] - cw)      # decay from t..C  (C, dh)
    k_dec = kb * w_tail
    s_new = jnp.exp(cw[-1])[:, None] * s + jax.lax.dot_general(
        k_dec, vb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new


def wkv6_scan(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,logw: (BH, S, dh); u: (BH, dh). Returns out (BH, S, dh) f32."""
    BH, S, dh = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    grid = (BH, n_chunks)
    u2 = u.reshape(BH, 1, dh)
    return pc.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, ic: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda b, ic: (b, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), jnp.float32),
        scratch_shapes=[pc.VMEM((dh, dh), jnp.float32)],
        compiler_params=pc.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(r, k, v, logw, u2)

"""Pallas TPU API compatibility layer across jax versions, plus the
contract-checked ``pallas_call`` entry point.

The Pallas TPU surface was renamed between jax 0.4.x and 0.5+:

===========================  =================================
jax 0.4.x                    jax 0.5+
===========================  =================================
``pltpu.TPUCompilerParams``  ``pltpu.CompilerParams``
``pltpu.TPUMemorySpace``     ``pltpu.MemorySpace``
===========================  =================================

Every kernel family (attention, qkv, decode, scan) imports the resolved
names from here instead of reaching into ``pltpu`` directly, so the same
kernel source runs on either jax line.  ``pltpu.VMEM(shape, dtype)``
scratch constructors and the ``dimension_semantics`` kwarg spelling are
stable across both lines and are re-exported for uniformity.

All kernel families also launch through :func:`pallas_call` below rather
than ``pl.pallas_call`` directly: a drop-in wrapper that, when the
static-analysis hook is enabled (``REPRO_KERNEL_CHECK=1``, or globally in
the test suite), validates the launch's BlockSpec/grid/VMEM contract
against the actual operands before dispatching — see
:mod:`repro.analysis.kernel_check`.  Disabled (the default), the only
overhead is one predicate call per launch.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# --- compiler params -------------------------------------------------------
# 0.5+ name first: on those versions TPUCompilerParams still exists but is a
# deprecated alias that warns.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# --- memory spaces ---------------------------------------------------------
MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
SMEM = MemorySpace.SMEM
ANY = MemorySpace.ANY

# VMEM is both a memory space and (called with (shape, dtype)) a scratch-
# buffer constructor on every supported jax; keep the pltpu object.
VMEM = pltpu.VMEM

# Scalar-prefetch grid spec (stable name on both lines): prefetched int32
# operands land in SMEM before the kernel runs and are visible to BlockSpec
# index_maps — the mechanism behind page-table-driven K/V gathers.
PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec


def compiler_params(*dimension_semantics: str, **kwargs):
    """Build compiler params with the given per-grid-dim semantics.

    ``compiler_params("parallel", "arbitrary")`` is the common call; extra
    kwargs (``vmem_limit_bytes`` etc.) pass through unchanged.
    """
    return CompilerParams(dimension_semantics=tuple(dimension_semantics),
                          **kwargs)


def pallas_call(kernel, **kwargs):
    """Contract-checked ``pl.pallas_call``.

    Same signature and return value as ``pl.pallas_call``; when
    :func:`repro.analysis.kernel_check.kernel_check_enabled` is true, the
    returned callable first validates block divisibility, index_map
    arity/bounds, output-grid coverage and the estimated VMEM footprint
    against the concrete operands (raising
    :class:`~repro.analysis.kernel_check.KernelContractError` with every
    violation) before delegating to the real launch.
    """
    inner = pl.pallas_call(kernel, **kwargs)

    def checked(*args):
        from repro.analysis import kernel_check
        if kernel_check.kernel_check_enabled():
            kernel_check.check_pallas_launch(kernel, kwargs, args)
        return inner(*args)

    return checked

"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_reference(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: float | None = None):
    """q: (BKV, group, dh); caches: (BKV, Skv, dh); cache_len: (BKV,)."""
    BKV, group, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(Skv)[None, :]
    ok = pos < cache_len[:, None]
    if window:
        ok &= pos > (cache_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bgk,bkd->bgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def gather_pages(pages, page_table):
    """Flatten a page pool into per-sequence contiguous caches.

    pages: (n_pages, page_size, KV, dh); page_table: (B, n_p) int32.
    Returns (B, n_p * page_size, KV, dh).
    """
    n_p, ps = page_table.shape[1], pages.shape[1]
    g = pages[page_table]                     # (B, n_p, ps, KV, dh)
    return g.reshape(g.shape[0], n_p * ps, *pages.shape[2:])


def gather_pages_int8(pages, scale_pool, page_table):
    """Dequantizing gather for int8 page pools (XLA oracle path).

    pages: (n_pages, page_size, KV, dh) int8; scale_pool: (n_pages,
    page_size, KV) fp32 per-token-per-kv-head scales; page_table: (B, n_p).
    Returns fp32 (B, n_p * page_size, KV, dh) — what the Pallas int8
    kernels compute tile-by-tile in VMEM, materialised whole.
    """
    g = gather_pages(pages, page_table).astype(jnp.float32)
    s = gather_pages(scale_pool[..., None], page_table)
    return g * s.astype(jnp.float32)


def chunk_prefill_reference(q, k_cache, v_cache, q_offset, *,
                            scale: float | None = None):
    """Dense oracle for the chunked-prefill kernels.

    q: (B, C, H, dh) at positions [q_offset, q_offset+C); caches:
    (B, Skv, KV, dh) with the chunk rows already written.  Query i sees
    cache position j iff j <= q_offset + i.  Returns (B, C, H, dh).
    """
    B, C, H, dh = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    k = jnp.repeat(k_cache, H // KV, axis=2)
    v = jnp.repeat(v_cache, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    ok = jnp.arange(Skv)[None, :] <= (q_offset + jnp.arange(C))[:, None]
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_chunk_prefill_reference(q, k_pages, v_pages, page_table, q_offset,
                                  *, scale: float | None = None):
    """Gather-based oracle for the paged chunked-prefill kernel."""
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    return chunk_prefill_reference(q, k, v, q_offset, scale=scale)


def paged_decode_reference(q, k_pages, v_pages, page_table, cache_len, *,
                           scale: float | None = None):
    """Gather-based oracle for the paged kernel.

    q: (B, KV, group, dh); pools: (n_pages, page_size, KV, dh);
    page_table: (B, n_p); cache_len: (B,).  Returns (B, KV, group, dh).
    """
    B, KV, group, dh = q.shape
    k = gather_pages(k_pages, page_table)     # (B, Skv, KV, dh)
    v = gather_pages(v_pages, page_table)
    qf = q.reshape(B * KV, group, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    lens = jnp.repeat(cache_len, KV)
    out = decode_reference(qf, kf, vf, lens, scale=scale)
    return out.reshape(B, KV, group, dh)


def paged_decode_reference_int8(q, k_pages, v_pages, k_scale, v_scale,
                                page_table, cache_len, *,
                                scale: float | None = None):
    """Dequantizing-gather oracle for the int8 paged decode kernel."""
    k = gather_pages_int8(k_pages, k_scale, page_table)
    v = gather_pages_int8(v_pages, v_scale, page_table)
    B, KV, group, dh = q.shape
    qf = q.reshape(B * KV, group, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    out = decode_reference(qf, kf, vf, jnp.repeat(cache_len, KV), scale=scale)
    return out.reshape(B, KV, group, dh)


def paged_chunk_prefill_reference_int8(q, k_pages, v_pages, k_scale, v_scale,
                                       page_table, q_offset, *,
                                       scale: float | None = None):
    """Dequantizing-gather oracle for the int8 paged chunk-prefill kernel."""
    k = gather_pages_int8(k_pages, k_scale, page_table)
    v = gather_pages_int8(v_pages, v_scale, page_table)
    return chunk_prefill_reference(q, k, v, q_offset, scale=scale)

"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_reference(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: float | None = None):
    """q: (BKV, group, dh); caches: (BKV, Skv, dh); cache_len: (BKV,)."""
    BKV, group, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(Skv)[None, :]
    ok = pos < cache_len[:, None]
    if window:
        ok &= pos > (cache_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bgk,bkd->bgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)

"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_reference(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: float | None = None):
    """q: (BKV, group, dh); caches: (BKV, Skv, dh); cache_len: (BKV,)."""
    BKV, group, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(Skv)[None, :]
    ok = pos < cache_len[:, None]
    if window:
        ok &= pos > (cache_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bgk,bkd->bgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def gather_pages(pages, page_table):
    """Flatten a page pool into per-sequence contiguous caches.

    pages: (n_pages, page_size, KV, dh); page_table: (B, n_p) int32.
    Returns (B, n_p * page_size, KV, dh).
    """
    n_p, ps = page_table.shape[1], pages.shape[1]
    g = pages[page_table]                     # (B, n_p, ps, KV, dh)
    return g.reshape(g.shape[0], n_p * ps, *pages.shape[2:])


def paged_decode_reference(q, k_pages, v_pages, page_table, cache_len, *,
                           scale: float | None = None):
    """Gather-based oracle for the paged kernel.

    q: (B, KV, group, dh); pools: (n_pages, page_size, KV, dh);
    page_table: (B, n_p); cache_len: (B,).  Returns (B, KV, group, dh).
    """
    B, KV, group, dh = q.shape
    k = gather_pages(k_pages, page_table)     # (B, Skv, KV, dh)
    v = gather_pages(v_pages, page_table)
    qf = q.reshape(B * KV, group, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, dh)
    lens = jnp.repeat(cache_len, KV)
    out = decode_reference(qf, kf, vf, lens, scale=scale)
    return out.reshape(B, KV, group, dh)

"""Chunked-prefill attention Pallas kernels (the serving prefill hot loop).

A fixed-shape chunk of C query tokens at absolute positions
``[q_offset, q_offset + C)`` attends causally to the resident prefix plus
its own chunk, already written into the KV cache — contiguous per-slot
stripes or a shared page pool.  One executable serves every (prompt
length, chunk index) pair: the offset arrives as a runtime scalar and the
page table is scalar-prefetched, exactly like ``paged_decode_attention``
in decode_attn.py — the paper's "reprogram loop bounds, never
re-synthesise" (§IV-C) applied to prefill.

GQA rides along as in the decode kernels: the rows of the query block are
the (group, chunk-position) pairs of one kv head — row ``g * C + c`` is
query head ``g`` at chunk position ``c`` — so a single K/V tile DMA feeds
every grouped query head and every chunk position at once (FAMOUS's
shared-K-BRAM PE grouping).

Correctness-over-speed note: the contiguous kernel's grid covers every
key tile of the cache and relies on the ``k_pos <= q_pos`` mask; tiles
entirely beyond the chunk contribute nothing.  Skipping them needs a
dynamic grid (offset-dependent) — one executable per offset — which is
exactly what this refactor removes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc

NEG_INF = -1e30


def _chunk_prefill_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                          m_ref, l_ref, *, scale: float, block_k: int,
                          n_k: int, chunk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (group*C, dh)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, dh)
    v = v_ref[0].astype(jnp.float32)
    off = off_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
    ok = k_pos <= off + c                              # causal incl. own chunk
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def chunk_prefill(q, k_cache, v_cache, q_offset, *, chunk: int,
                  scale: float | None = None, block_k: int = 512,
                  interpret: bool = False):
    """q: (BKV, group*C, dh) with row = g*C + c; caches: (BKV, Skv, dh);
    q_offset: () int32 runtime scalar.  Returns (BKV, group*C, dh)."""
    BKV, rows, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0, (Skv, block_k)
    assert rows % chunk == 0, (rows, chunk)
    n_k = Skv // block_k
    off = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)
    kernel = functools.partial(_chunk_prefill_kernel, scale=float(scale),
                               block_k=block_k, n_k=n_k, chunk=chunk)
    return pc.pallas_call(
        kernel,
        grid=(BKV, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, ik: (0, 0), memory_space=pc.SMEM),
            pl.BlockSpec((1, rows, dh), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, dh), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, rows, dh), q.dtype),
        scratch_shapes=[
            pc.VMEM((rows, dh), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
        ],
        compiler_params=pc.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(off, q, k_cache, v_cache)


def _paged_chunk_prefill_kernel(off_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                                acc_ref, m_ref, l_ref, *, scale: float,
                                page_size: int, n_p: int, chunk: int):
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group*C, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page_size, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    off = off_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    k_pos = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
    ok = k_pos <= off + c
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _flush():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_chunk_prefill(q, k_pages, v_pages, page_table, q_offset, *,
                        chunk: int, scale: float | None = None,
                        interpret: bool = False):
    """Page-table-indexed chunked-prefill attention.

    q: (B, KV, group*C, dh) with row = g*C + c; pools: (n_pages, page_size,
    KV, dh); page_table: (B, n_p) int32; q_offset: () int32 runtime scalar.
    Returns (B, KV, group*C, dh).  The page table and offset are
    scalar-prefetched — the K/V BlockSpec index_maps read
    ``page_table[b, ip]`` to aim each page DMA, so the grid program never
    changes shape when prompts grow or chunks advance.
    """
    B, KV, rows, dh = q.shape
    page_size = k_pages.shape[1]
    n_p = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    assert rows % chunk == 0, (rows, chunk)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kernel = functools.partial(_paged_chunk_prefill_kernel, scale=float(scale),
                               page_size=page_size, n_p=n_p, chunk=chunk)
    grid_spec = pc.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # q_offset, page_table
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, rows, dh),
                         lambda b, g, ip, off, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda b, g, ip, off, pt: (b, g, 0, 0)),
        scratch_shapes=[
            pc.VMEM((rows, dh), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
        ],
    )
    return pc.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rows, dh), q.dtype),
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(off, page_table.astype(jnp.int32), q, k_pages, v_pages)


def _paged_chunk_prefill_kernel_int8(off_ref, pt_ref, q_ref, k_ref, v_ref,
                                     ks_ref, vs_ref, o_ref, acc_ref, m_ref,
                                     l_ref, *, scale: float, page_size: int,
                                     n_p: int, chunk: int):
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group*C, dh)
    # dequantize in VMEM (see decode_attn._paged_decode_kernel_int8)
    ks = ks_ref[0, :, :].astype(jnp.float32)          # (page_size, 1)
    vs = vs_ref[0, :, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks    # (page_size, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs
    off = off_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    k_pos = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
    ok = k_pos <= off + c
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _flush():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_chunk_prefill_int8(q, k_pages, v_pages, k_scale, v_scale,
                             page_table, q_offset, *, chunk: int,
                             scale: float | None = None,
                             interpret: bool = False):
    """Int8 paged chunked-prefill attention with in-kernel dequantization.

    Same contract as ``paged_chunk_prefill`` except the K/V pools are int8
    and carry fp32 per-token-per-kv-head scale pools of shape
    (n_pages, page_size, KV); the scale blocks ride the same page-table
    index_map and widen the int8 page to fp32 only in VMEM.
    """
    B, KV, rows, dh = q.shape
    page_size = k_pages.shape[1]
    n_p = page_table.shape[1]
    assert k_pages.dtype == jnp.int8 and v_pages.dtype == jnp.int8
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    assert rows % chunk == 0, (rows, chunk)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1)
    kernel = functools.partial(_paged_chunk_prefill_kernel_int8,
                               scale=float(scale), page_size=page_size,
                               n_p=n_p, chunk=chunk)
    grid_spec = pc.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # q_offset, page_table
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, rows, dh),
                         lambda b, g, ip, off, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, ip, off, pt: (pt[b, ip], 0, g)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda b, g, ip, off, pt: (b, g, 0, 0)),
        scratch_shapes=[
            pc.VMEM((rows, dh), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
            pc.VMEM((rows, 1), jnp.float32),
        ],
    )
    return pc.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rows, dh), q.dtype),
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(off, page_table.astype(jnp.int32), q, k_pages, v_pages,
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))

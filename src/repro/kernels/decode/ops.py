"""Jitted wrappers for the decode / chunked-prefill attention kernels:
(B, S, H, dh) model layout to the kernels' GQA-flattened row layouts.

The contiguous wrappers flatten **kv-major** — row ``kv * B + b``, not
``b * KV + kv`` — so the merged row dim is a concatenation of contiguous
per-kv-head blocks.  Under tensor parallelism the caches shard over the
kv-head dim; kv-major keeps each device's rows a contiguous slab of the
flattened operand (b-major would interleave shards token-by-token), so
GSPMD partitions the reshape instead of all-gathering around it.  Row
order is otherwise irrelevant: rows are independent, and the inverse
transpose restores the exact (B, S, H, dh) layout, bit for bit.  The
paged wrappers keep (B, KV, group, dh) unflattened — already shardable."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode import chunk_prefill as chunk_kernels
from repro.kernels.decode import decode_attn


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "scale", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, scale=None,
                     block_k=512, interpret=None):
    """q: (B, 1, H, dh); caches: (B, Skv, KV, dh); cache_len: (B,) int32.
    Returns (B, 1, H, dh)."""
    B, _, H, dh = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    # (B, 1, H, dh) -> (B, KV, group, dh) -> kv-major (KV*B, group, dh)
    qf = (q[:, 0].reshape(B, KV, group, dh).transpose(1, 0, 2, 3)
          .reshape(KV * B, group, dh))
    kf = k_cache.transpose(2, 0, 1, 3).reshape(KV * B, Skv, dh)
    vf = v_cache.transpose(2, 0, 1, 3).reshape(KV * B, Skv, dh)
    lens = jnp.tile(cache_len, KV)
    out = decode_attn.decode_attention(qf, kf, vf, lens, window=window,
                                       scale=scale, block_k=block_k,
                                       interpret=interpret)
    return (out.reshape(KV, B, group, dh).transpose(1, 0, 2, 3)
            .reshape(B, 1, H, dh))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           scale=None, interpret=None):
    """q: (B, 1, H, dh); pools: (n_pages, page_size, KV, dh);
    page_table: (B, n_p) int32; cache_len: (B,) int32.
    Returns (B, 1, H, dh)."""
    B, _, H, dh = q.shape
    KV = k_pages.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = q[:, 0].reshape(B, KV, group, dh)
    out = decode_attn.paged_decode_attention(qf, k_pages, v_pages,
                                             page_table, cache_len,
                                             scale=scale, interpret=interpret)
    return out.reshape(B, 1, H, dh)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_int8(q, k_pages, v_pages, k_scale, v_scale,
                                page_table, cache_len, *, scale=None,
                                interpret=None):
    """Int8 variant of :func:`paged_decode_attention`: pools are int8 with
    fp32 (n_pages, page_size, KV) scale pools, dequantized in-kernel."""
    B, _, H, dh = q.shape
    KV = k_pages.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = q[:, 0].reshape(B, KV, group, dh)
    out = decode_attn.paged_decode_attention_int8(
        qf, k_pages, v_pages, k_scale, v_scale, page_table, cache_len,
        scale=scale, interpret=interpret)
    return out.reshape(B, 1, H, dh)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def verify_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     block_k=512, interpret=None):
    """Speculative verify on the decode kernel: q: (B, W, H, dh) at per-slot
    positions ``cache_len[b] + j`` (K/V already written); caches:
    (B, Skv, KV, dh); cache_len: (B,) int32.  Returns (B, W, H, dh).

    Each (slot, verify position) pair becomes its own kernel row with
    length ``cache_len[b] + j + 1`` — the decode kernel already supports
    per-row lengths, so verify needs no new Pallas code, only this
    flattening (which broadcasts each slot's cache W ways; acceptable for
    the small ``W = draft_k + 1`` the engine uses).
    """
    B, W, H, dh = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    # (B, W, H, dh) -> (B, W, KV, group, dh) -> kv-major (KV*B*W, group, dh)
    qf = (q.reshape(B, W, KV, group, dh).transpose(2, 0, 1, 3, 4)
          .reshape(KV * B * W, group, dh))
    kf = jnp.broadcast_to(k_cache.transpose(2, 0, 1, 3)[:, :, None],
                          (KV, B, W, Skv, dh)).reshape(KV * B * W, Skv, dh)
    vf = jnp.broadcast_to(v_cache.transpose(2, 0, 1, 3)[:, :, None],
                          (KV, B, W, Skv, dh)).reshape(KV * B * W, Skv, dh)
    # pad rows past a slot's real draft may exceed Skv — clip (their
    # output is discarded by the engine's accept loop anyway)
    lens = jnp.minimum(cache_len[:, None] + jnp.arange(W, dtype=jnp.int32)
                       + 1, Skv)
    out = decode_attn.decode_attention(qf, kf, vf,
                                       jnp.tile(lens.reshape(-1), KV),
                                       window=0, scale=scale,
                                       block_k=block_k, interpret=interpret)
    return (out.reshape(KV, B, W, group, dh).transpose(1, 2, 0, 3, 4)
            .reshape(B, W, H, dh))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           scale=None, interpret=None):
    """Paged speculative verify: q: (B, W, H, dh); pools:
    (n_pages, page_size, KV, dh); page_table: (B, n_p) int32; cache_len:
    (B,) int32.  Returns (B, W, H, dh).  Same flattening as
    :func:`verify_attention`, on the scalar-prefetched page-table kernel —
    only the page *table* is repeated per verify position (a few ints per
    row), never the pool itself."""
    B, W, H, dh = q.shape
    ps, KV = k_pages.shape[1], k_pages.shape[2]
    n_p = page_table.shape[1]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = q.reshape(B * W, KV, group, dh)
    pt = jnp.broadcast_to(page_table[:, None], (B, W, n_p)).reshape(B * W, n_p)
    lens = jnp.minimum(cache_len[:, None] + jnp.arange(W, dtype=jnp.int32)
                       + 1, n_p * ps).reshape(-1)
    out = decode_attn.paged_decode_attention(qf, k_pages, v_pages, pt, lens,
                                             scale=scale, interpret=interpret)
    return out.reshape(B, W, H, dh)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention_int8(q, k_pages, v_pages, k_scale, v_scale,
                                page_table, cache_len, *, scale=None,
                                interpret=None):
    """Int8 variant of :func:`paged_verify_attention` — same page-table
    broadcast, riding the int8 paged decode kernel."""
    B, W, H, dh = q.shape
    ps, KV = k_pages.shape[1], k_pages.shape[2]
    n_p = page_table.shape[1]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = q.reshape(B * W, KV, group, dh)
    pt = jnp.broadcast_to(page_table[:, None], (B, W, n_p)).reshape(B * W, n_p)
    lens = jnp.minimum(cache_len[:, None] + jnp.arange(W, dtype=jnp.int32)
                       + 1, n_p * ps).reshape(-1)
    out = decode_attn.paged_decode_attention_int8(
        qf, k_pages, v_pages, k_scale, v_scale, pt, lens,
        scale=scale, interpret=interpret)
    return out.reshape(B, W, H, dh)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def chunk_prefill_attention(q, k_cache, v_cache, q_offset, *, scale=None,
                            block_k=512, interpret=None):
    """q: (B, C, H, dh) at positions [q_offset, q_offset+C); caches:
    (B, Skv, KV, dh) with the chunk rows already written; q_offset: ()
    int32 runtime scalar.  Returns (B, C, H, dh)."""
    B, C, H, dh = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    # (B, C, H, dh) -> (KV, B, group, C, dh) -> kv-major (KV*B, group*C, dh)
    qf = (q.reshape(B, C, KV, group, dh).transpose(2, 0, 3, 1, 4)
          .reshape(KV * B, group * C, dh))
    kf = k_cache.transpose(2, 0, 1, 3).reshape(KV * B, Skv, dh)
    vf = v_cache.transpose(2, 0, 1, 3).reshape(KV * B, Skv, dh)
    out = chunk_kernels.chunk_prefill(qf, kf, vf, q_offset, chunk=C,
                                      scale=scale, block_k=block_k,
                                      interpret=interpret)
    return (out.reshape(KV, B, group, C, dh).transpose(1, 3, 0, 2, 4)
            .reshape(B, C, H, dh))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_chunk_prefill_attention(q, k_pages, v_pages, page_table, q_offset,
                                  *, scale=None, interpret=None):
    """q: (B, C, H, dh); pools: (n_pages, page_size, KV, dh); page_table:
    (B, n_p) int32; q_offset: () int32.  Returns (B, C, H, dh)."""
    B, C, H, dh = q.shape
    KV = k_pages.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = (q.reshape(B, C, KV, group, dh).transpose(0, 2, 3, 1, 4)
          .reshape(B, KV, group * C, dh))
    out = chunk_kernels.paged_chunk_prefill(qf, k_pages, v_pages, page_table,
                                            q_offset, chunk=C, scale=scale,
                                            interpret=interpret)
    return (out.reshape(B, KV, group, C, dh).transpose(0, 3, 1, 2, 4)
            .reshape(B, C, H, dh))


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_chunk_prefill_attention_int8(q, k_pages, v_pages, k_scale, v_scale,
                                       page_table, q_offset, *, scale=None,
                                       interpret=None):
    """Int8 variant of :func:`paged_chunk_prefill_attention`."""
    B, C, H, dh = q.shape
    KV = k_pages.shape[2]
    group = H // KV
    interpret = _interpret_default() if interpret is None else interpret
    qf = (q.reshape(B, C, KV, group, dh).transpose(0, 2, 3, 1, 4)
          .reshape(B, KV, group * C, dh))
    out = chunk_kernels.paged_chunk_prefill_int8(
        qf, k_pages, v_pages, k_scale, v_scale, page_table, q_offset,
        chunk=C, scale=scale, interpret=interpret)
    return (out.reshape(B, KV, group, C, dh).transpose(0, 3, 1, 2, 4)
            .reshape(B, C, H, dh))
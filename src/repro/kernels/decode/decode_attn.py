"""Single-token decode attention Pallas kernel (serving hot loop).

One query token per sequence attends to a (possibly ring-buffered) KV cache.
Grid: (B·KV, Skv/block_k) — key tiles stream sequentially with online
softmax; the per-kv-head group of query heads (GQA) rides along as the row
dimension of the (group, dh) query block, so one cache DMA feeds all grouped
query heads (FAMOUS's shared-K-BRAM PE grouping).

``cache_len`` masking uses a scalar read from a (B, 1) int32 input —
the runtime-programmable "sequence length register" of the paper's µB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, block_k: int, n_k: int,
                   window: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (group, dh)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, dh)
    v = v_ref[0].astype(jnp.float32)
    valid_len = len_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, bk)
    pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = pos < valid_len
    if window:
        ok &= pos > valid_len - 1 - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """q: (BKV, group, dh); caches: (BKV, Skv, dh); cache_len: (BKV,) int32.
    Returns (BKV, group, dh)."""
    BKV, group, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0
    n_k = Skv // block_k
    grid = (BKV, n_k)
    lens = cache_len.reshape(BKV, 1).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, scale=float(scale),
                               block_k=block_k, n_k=n_k, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, ik: (b, 0),
                         memory_space=pc.SMEM),
            pl.BlockSpec((1, group, dh), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, dh), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, group, dh), q.dtype),
        scratch_shapes=[
            pc.VMEM((group, dh), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=pc.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)

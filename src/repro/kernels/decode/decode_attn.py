"""Single-token decode attention Pallas kernel (serving hot loop).

One query token per sequence attends to a (possibly ring-buffered) KV cache.
Grid: (B·KV, Skv/block_k) — key tiles stream sequentially with online
softmax; the per-kv-head group of query heads (GQA) rides along as the row
dimension of the (group, dh) query block, so one cache DMA feeds all grouped
query heads (FAMOUS's shared-K-BRAM PE grouping).

``cache_len`` masking uses a scalar read from a (B, 1) int32 input —
the runtime-programmable "sequence length register" of the paper's µB.

Two cache layouts share the online-softmax inner loop:

  * ``decode_attention``       — contiguous (BKV, Skv, dh) per-slot caches.
  * ``paged_decode_attention`` — a shared (n_pages, page_size, KV, dh) page
    pool; a scalar-prefetched per-slot page table drives the K/V BlockSpec
    index_map, so each key tile is DMA'd straight from its page (no gather
    materialisation), the TPU analogue of FAMOUS's banked-BRAM tiling.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale: float, block_k: int, n_k: int,
                   window: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (group, dh)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, dh)
    v = v_ref[0].astype(jnp.float32)
    valid_len = len_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, bk)
    pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = pos < valid_len
    if window:
        ok &= pos > valid_len - 1 - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         page_size: int, n_p: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page_size, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    valid_len = len_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, ps)
    pos = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = pos < valid_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _flush():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel_int8(len_ref, pt_ref, q_ref, k_ref, v_ref, ks_ref,
                              vs_ref, o_ref, acc_ref, m_ref, l_ref, *,
                              scale: float, page_size: int, n_p: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (group, dh)
    # dequantize in VMEM: int8 page x per-token fp32 scale -> fp32 tile.
    # HBM only ever streams the int8 bytes + one scale row per page.
    ks = ks_ref[0, :, :].astype(jnp.float32)          # (page_size, 1)
    vs = vs_ref[0, :, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks    # (page_size, dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs
    valid_len = len_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (group, ps)
    pos = ip * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = pos < valid_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _flush():
        o_ref[0, 0, ...] = (acc_ref[...]
                            / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """Page-table-indexed decode attention.

    q: (B, KV, group, dh); pools: (n_pages, page_size, KV, dh);
    page_table: (B, n_p) int32 page ids; cache_len: (B,) int32.
    Returns (B, KV, group, dh).

    The page table and lengths are *scalar-prefetched*: they reach SMEM
    before the kernel body runs, and the K/V BlockSpec index_maps read
    ``page_table[b, ip]`` to aim each page DMA — the grid program never
    changes shape when sequences grow or move, only the prefetched indices
    do (the paper's µB reprograms addresses, never re-synthesises).
    """
    B, KV, group, dh = q.shape
    n_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    n_p = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    kernel = functools.partial(_paged_decode_kernel, scale=float(scale),
                               page_size=page_size, n_p=n_p)
    grid_spec = pc.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # cache_len, page_table
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, g, ip, lens, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, g, ip, lens, pt: (b, g, 0, 0)),
        scratch_shapes=[
            pc.VMEM((group, dh), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
        ],
    )
    return pc.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, dh), q.dtype),
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), page_table.astype(jnp.int32),
      q, k_pages, v_pages)


def paged_decode_attention_int8(q, k_pages, v_pages, k_scale, v_scale,
                                page_table, cache_len, *,
                                scale: float | None = None,
                                interpret: bool = False):
    """Int8 paged decode attention with in-kernel dequantization.

    q: (B, KV, group, dh) fp; pools: (n_pages, page_size, KV, dh) **int8**;
    scale pools: (n_pages, page_size, KV) fp32 per-token-per-kv-head scales;
    page_table: (B, n_p) int32; cache_len: (B,) int32.

    The scale pools ride the same page-table index_map as K/V, so each grid
    step DMAs one int8 page plus its (page_size, 1) scale column and widens
    to fp32 only in VMEM — HBM traffic per token drops from 4 B/elem to
    1 B/elem + 4 B/head (FAMOUS's 8-bit fixed-point operands, paged).
    """
    B, KV, group, dh = q.shape
    n_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    n_p = page_table.shape[1]
    assert k_pages.dtype == jnp.int8 and v_pages.dtype == jnp.int8
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    kernel = functools.partial(_paged_decode_kernel_int8, scale=float(scale),
                               page_size=page_size, n_p=n_p)
    grid_spec = pc.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # cache_len, page_table
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, group, dh),
                         lambda b, g, ip, lens, pt: (b, g, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1, dh),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g, 0)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g)),
            pl.BlockSpec((1, page_size, 1),
                         lambda b, g, ip, lens, pt: (pt[b, ip], 0, g)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dh),
                               lambda b, g, ip, lens, pt: (b, g, 0, 0)),
        scratch_shapes=[
            pc.VMEM((group, dh), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
        ],
    )
    return pc.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, dh), q.dtype),
        compiler_params=pc.compiler_params("parallel", "parallel",
                                           "arbitrary"),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), page_table.astype(jnp.int32),
      q, k_pages, v_pages, k_scale.astype(jnp.float32),
      v_scale.astype(jnp.float32))


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False):
    """q: (BKV, group, dh); caches: (BKV, Skv, dh); cache_len: (BKV,) int32.
    Returns (BKV, group, dh)."""
    BKV, group, dh = q.shape
    Skv = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    block_k = min(block_k, Skv)
    assert Skv % block_k == 0
    n_k = Skv // block_k
    grid = (BKV, n_k)
    lens = cache_len.reshape(BKV, 1).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, scale=float(scale),
                               block_k=block_k, n_k=n_k, window=window)
    return pc.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, ik: (b, 0),
                         memory_space=pc.SMEM),
            pl.BlockSpec((1, group, dh), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, dh), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, group, dh), q.dtype),
        scratch_shapes=[
            pc.VMEM((group, dh), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
            pc.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=pc.compiler_params("parallel", "arbitrary"),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)

"""Pure-jnp oracles for the tiled QKV projection kernel (Algorithm 1)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import quant as quant_lib


def matmul_reference(x, w, out_dtype=None):
    return jnp.einsum("td,df->tf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(out_dtype or x.dtype)


def matmul_int8_reference(xq, wq, sx, sw, out_dtype=jnp.float32):
    acc = jnp.einsum("td,df->tf", xq.astype(jnp.int32), wq.astype(jnp.int32))
    return (acc.astype(jnp.float32) * sx * sw).astype(out_dtype)


def qkv_reference(x, wq, wk, wv, bq=None, bk=None, bv=None):
    """x: (B, S, D); w*: (D, H, dh) -> q/k/v (B, S, H, dh)."""
    def one(w, b):
        y = jnp.einsum("bsd,dhe->bshe", x.astype(jnp.float32),
                       w.astype(jnp.float32))
        if b is not None:
            y = y + b.astype(jnp.float32)
        return y.astype(x.dtype)

    return one(wq, bq), one(wk, bk), one(wv, bv)


quantize = quant_lib.quantize

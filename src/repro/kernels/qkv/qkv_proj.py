"""Tiled fused QKV projection Pallas kernel — FAMOUS Algorithm 1 on TPU.

The weight matrices are tiled along the *reduction* dimension (the paper's
column tiling, TS = ``block_d``): each grid step DMAs one (block_t × block_d)
X tile — read once, used for all of Q, K and V like the shared X_i BRAM —
and one (block_d × block_f) tile of the fused [Wq|Wk|Wv] matrix, accumulating
partial products in a VMEM f32 scratch exactly as the FPGA accumulates
per-tile partial sums across BRAM reloads.

Grid: (T/block_t, F/block_f, D/block_d), reduction innermost ("arbitrary").

int8 variant (the paper's 8-bit fixed point): int8×int8→int32 MXU dot,
dequantised on flush by per-token and per-column scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat as pc


def _proj_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    i_d = pl.program_id(2)

    @pl.when(i_d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i_d == n_d - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _proj_kernel_int8(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                      n_d: int):
    i_d = pl.program_id(2)

    @pl.when(i_d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(i_d == n_d - 1)
    def _flush():
        deq = (acc_ref[...].astype(jnp.float32)
               * sx_ref[...] * sw_ref[...])
        o_ref[...] = deq.astype(o_ref.dtype)


def _matmul_call(x, w, block_t, block_f, block_d, out_dtype, interpret):
    """x: (T, D) @ w: (D, F) -> (T, F), reduction-tiled (TS = block_d)."""
    T, D = x.shape
    _, F = w.shape
    block_t = min(block_t, T)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert T % block_t == 0 and F % block_f == 0 and D % block_d == 0
    n_d = D // block_d
    grid = (T // block_t, F // block_f, n_d)
    return pc.pallas_call(
        functools.partial(_proj_kernel, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_d), lambda it, jf, kd: (it, kd)),
            pl.BlockSpec((block_d, block_f), lambda it, jf, kd: (kd, jf)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda it, jf, kd: (it, jf)),
        out_shape=jax.ShapeDtypeStruct((T, F), out_dtype or x.dtype),
        scratch_shapes=[pc.VMEM((block_t, block_f), jnp.float32)],
        compiler_params=pc.compiler_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _matmul_vjp(x, w, block_t, block_f, block_d, out_dtype, interpret):
    return _matmul_call(x, w, block_t, block_f, block_d, out_dtype, interpret)


def _matmul_vjp_fwd(x, w, block_t, block_f, block_d, out_dtype, interpret):
    return _matmul_vjp(x, w, block_t, block_f, block_d, out_dtype,
                       interpret), (x, w)


def _matmul_vjp_bwd(block_t, block_f, block_d, out_dtype, interpret, res, g):
    # The backward of a matmul is two matmuls — run them through the same
    # tiled kernel, with the block roles permuted to follow each operand's
    # dims: dX = g·Wᵀ is (T,F)@(F,D); dW = Xᵀ·g is (D,T)@(T,F).
    x, w = res
    dx = _matmul_call(g, w.T, block_t, block_d, block_f, x.dtype, interpret)
    dw = _matmul_call(x.T, g, block_d, block_f, block_t, w.dtype, interpret)
    return dx, dw


_matmul_vjp.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def matmul_tiled(x, w, *, block_t: int = 256, block_f: int = 256,
                 block_d: int = 512, out_dtype=None,
                 interpret: bool = False):
    """x: (T, D) @ w: (D, F) -> (T, F), reduction-tiled (TS = block_d).
    Differentiable: dX/dW are computed by the same Pallas kernel."""
    return _matmul_vjp(x, w, block_t, block_f, block_d, out_dtype, interpret)


def matmul_tiled_int8(xq, wq, sx, sw, *, block_t: int = 256,
                      block_f: int = 256, block_d: int = 512,
                      out_dtype=jnp.float32, interpret: bool = False):
    """xq: (T, D) int8, wq: (D, F) int8, sx: (T, 1) f32, sw: (1, F) f32."""
    T, D = xq.shape
    _, F = wq.shape
    block_t = min(block_t, T)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert T % block_t == 0 and F % block_f == 0 and D % block_d == 0
    n_d = D // block_d
    grid = (T // block_t, F // block_f, n_d)
    return pc.pallas_call(
        functools.partial(_proj_kernel_int8, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, block_d), lambda it, jf, kd: (it, kd)),
            pl.BlockSpec((block_d, block_f), lambda it, jf, kd: (kd, jf)),
            pl.BlockSpec((block_t, 1), lambda it, jf, kd: (it, 0)),
            pl.BlockSpec((1, block_f), lambda it, jf, kd: (0, jf)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda it, jf, kd: (it, jf)),
        out_shape=jax.ShapeDtypeStruct((T, F), out_dtype),
        scratch_shapes=[pc.VMEM((block_t, block_f), jnp.int32)],
        compiler_params=pc.compiler_params("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(xq, wq, sx, sw)

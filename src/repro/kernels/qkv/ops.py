"""Jitted wrapper: fused Q/K/V projection via the tiled matmul kernel.

Fuses [Wq|Wk|Wv] into one (D, F) matrix so the X tile is read once per grid
step and feeds all three projections — the QKV_PM shared-X-BRAM trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib
from repro.kernels.qkv import qkv_proj


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_d", "quant", "interpret"))
def qkv_projection(x, wq, wk, wv, bq=None, bk=None, bv=None, *,
                   tile_d: int = 512, quant: str = "none", interpret=None):
    """x: (B, S, D); w*: (D, H|KV, dh). Returns (q, k, v)."""
    B, S, D = x.shape
    interpret = _interpret_default() if interpret is None else interpret
    shapes = [wq.shape[1:], wk.shape[1:], wv.shape[1:]]
    w = jnp.concatenate([wq.reshape(D, -1), wk.reshape(D, -1),
                         wv.reshape(D, -1)], axis=-1)
    xt = x.reshape(B * S, D)
    if quant == "int8":
        xq, sx = quant_lib.quantize(xt, axis=1)
        wqz, sw = quant_lib.quantize(w, axis=0)
        out = qkv_proj.matmul_tiled_int8(
            xq, wqz, sx, sw, block_d=tile_d, out_dtype=jnp.float32,
            interpret=interpret).astype(x.dtype)
    else:
        out = qkv_proj.matmul_tiled(xt, w, block_d=tile_d,
                                    interpret=interpret)
    nq = shapes[0][0] * shapes[0][1]
    nk = shapes[1][0] * shapes[1][1]
    q = out[:, :nq].reshape(B, S, *shapes[0])
    k = out[:, nq:nq + nk].reshape(B, S, *shapes[1])
    v = out[:, nq + nk:].reshape(B, S, *shapes[2])
    if bq is not None:
        q = q + bq.astype(q.dtype)
        k = k + bk.astype(k.dtype)
        v = v + bv.astype(v.dtype)
    return q, k, v

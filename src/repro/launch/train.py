"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires the full production path end to end: config -> mesh -> shardings ->
deterministic sharded data pipeline -> jitted train step -> fault-tolerant
Trainer with async checkpointing and SIGTERM-preemption handling.  On this
CPU container it runs reduced configs (use ``--smoke``); on a real cluster
the same file runs the full configs (the mesh/sharding logic is identical —
proven by the dry-run).
"""
from __future__ import annotations

import argparse
import functools
import signal

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, SMOKE_SHAPES, ShapeConfig, get_config, shrink
from repro.core.famous import FamousConfig
from repro.data import pipeline
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.parallel import sharding as shd
from repro.train import step as step_lib
from repro.train import trainer as trainer_lib


def build(arch: str, shape: ShapeConfig, *, smoke: bool, mesh=None,
          tcfg: step_lib.TrainConfig | None = None,
          fcfg: FamousConfig | None = None, seed: int = 0):
    cfg = get_config(arch)
    if smoke:
        cfg = shrink(cfg)
    fcfg = fcfg or FamousConfig(impl="xla")
    tcfg = tcfg or step_lib.TrainConfig(
        compute_dtype=jnp.float32 if smoke else jnp.bfloat16)
    mesh = mesh or (make_smoke_mesh() if smoke else make_production_mesh())

    state_axes = step_lib.state_logical_axes(cfg)
    state_shapes = step_lib.state_shapes(cfg, tcfg)
    state_sh = shd.tree_shardings(mesh, state_axes, None, state_shapes)
    train_step = step_lib.make_train_step(cfg, fcfg, tcfg)

    with mesh:
        state = jax.jit(
            functools.partial(step_lib.init_state, cfg, tcfg),
            out_shardings=state_sh)(jax.random.PRNGKey(seed))
        jitted = jax.jit(train_step, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None), donate_argnums=0)

    batch_sharding = shd.batch_sharding(
        mesh, 2, None, (shape.global_batch, shape.seq_len))

    def batch_fn(step: int):
        return pipeline.make_global_batch(cfg, shape, seed, step,
                                          batch_sharding)

    return cfg, mesh, state, jitted, batch_fn, state_sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="famous-bert")
    ap.add_argument("--shape", default="smoke_train")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    shape = {**SHAPES, **SMOKE_SHAPES}[args.shape]
    cfg, mesh, state, jitted, batch_fn, state_sh = build(
        args.arch, shape, smoke=args.smoke, seed=args.seed)

    tcfg = trainer_lib.TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir)
    tr = trainer_lib.Trainer(jitted, state, batch_fn, tcfg,
                             state_shardings=state_sh)
    signal.signal(signal.SIGTERM, lambda *_: tr.request_stop())

    with mesh:
        tr.run()
    for m in tr.metrics_log[-5:]:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in m.items()})
    print(f"done: arch={cfg.name} steps={int(tr.state['step'])} "
          f"restarts={tr.restarts} stragglers={len(tr.straggler_events)}")


if __name__ == "__main__":
    main()

"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Drives the Scheduler/Runtime continuous-batching engine over a synthetic
request stream on a reduced config (CPU container); the chunked-prefill /
decode step functions are the same ones the multi-pod dry-run lowers at
production shapes.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, get_config, shrink
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.obs.runtime import Observer
from repro.obs.trace import now
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="famous-bert")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-mode", choices=("chunked", "monolithic"),
                    default="chunked",
                    help="chunked: fixed-shape prefill chunks interleaved "
                         "with decode under the token budget (O(1) "
                         "executables); monolithic: whole-prompt prefill at "
                         "admission (legacy comparison baseline)")
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk length (must divide max-seq)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-step token budget for the scheduler; "
                         "0 = slots + chunk (one chunk per step while "
                         "decoding). Larger = faster TTFT for long prompts, "
                         "burstier decode (see docs/serving.md)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request "
                         "(0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k filter (0 = full vocab)")
    ap.add_argument("--cache-kind", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV-cache layout: per-slot max_seq stripes, or a "
                         "shared page pool with per-slot page tables")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="pool pages incl. the null page (paged mode); "
                         "0 = full contiguous-equivalent capacity — pass "
                         "less to oversubscribe")
    ap.add_argument("--kv-dtype", choices=("fp", "int8"), default="fp",
                    help="KV-cache element type (paged only): int8 stores "
                         "quantized pages plus per-token-per-kv-head fp32 "
                         "scale pools and dequantizes inside the attention "
                         "kernels — ~3.2x the live-token capacity per byte "
                         "at dh=16, lossy (bounded logit drift; see "
                         "docs/serving.md)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical full prompt blocks across "
                         "requests via refcounted pages (paged + chunked "
                         "only; recurrent/hybrid archs fall back to cold "
                         "prefill)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-model-free speculative decoding: a host-side "
                         "prompt-lookup drafter proposes tokens, one batched "
                         "verify step accepts the longest matching prefix "
                         "(token-identical to plain decode; recurrent/hybrid "
                         "archs fall back to plain decode)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max draft tokens per verify step (the verify "
                         "executable's fixed width is draft-k + 1)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor parallelism: shard attention heads / kv "
                         "heads / FFN hidden over a 'model' mesh axis of "
                         "this size (needs tp visible devices; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count="
                         "N). 1 = unsharded single-device baseline")
    ap.add_argument("--mesh-shape", default="",
                    help="explicit 'data,model' mesh shape, e.g. '1,2' "
                         "(overrides --tp; the data axis is reserved for "
                         "engine replicas)")
    ap.add_argument("--metrics", action="store_true",
                    help="attach an Observer and print the Prometheus text "
                         "exposition after the run (host-pure counters/"
                         "gauges/histograms; see docs/observability.md)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="also write the exposition to PATH (implies "
                         "--metrics)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="record per-step-phase trace events and write "
                         "Chrome/Perfetto trace_event JSON to PATH "
                         "(implies --metrics)")
    args = ap.parse_args()

    if args.mesh_shape:
        dp, tp = (int(x) for x in args.mesh_shape.split(","))
    else:
        dp, tp = 1, args.tp
    run = RunConfig(arch=args.arch, tp=tp, dp=dp)
    mesh = run.make_mesh()   # None when tp == dp == 1

    cfg = shrink(get_config(args.arch))
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    obs = (Observer(trace=bool(args.trace_out))
           if args.metrics or args.metrics_out or args.trace_out else None)
    params = module.init_params(transformer.model_spec(cfg),
                                jax.random.PRNGKey(args.seed), jnp.float32)
    engine = ServingEngine(params, cfg, FamousConfig(impl="xla"),
                           mesh=mesh, observer=obs,
                           n_slots=args.slots, max_seq=args.max_seq,
                           cache_kind=args.cache_kind,
                           page_size=args.page_size,
                           n_pages=args.n_pages or None,
                           prefill_mode=args.prefill_mode,
                           chunk=args.chunk,
                           token_budget=args.token_budget,
                           prefix_cache=args.prefix_cache,
                           speculative=args.speculative,
                           draft_k=args.draft_k,
                           kv_dtype=args.kv_dtype)
    if mesh is not None:
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} over "
              f"{mesh.devices.size} of {jax.device_count()} devices; "
              f"kv/state cache {engine.cache_bytes_per_device()} "
              f"bytes/device")
    if engine.paged:
        cache_bytes = sum(b.size * b.dtype.itemsize for b in
                          jax.tree_util.tree_leaves(engine.caches))
        pc = engine.pcfg
        print(f"kv cache: paged dtype={engine.kv_dtype}, "
              f"{pc.n_pages} pages x {pc.page_size} tokens "
              f"({cache_bytes // pc.n_pages} bytes/page incl. scales), "
              f"live-token capacity={(pc.n_pages - 1) * pc.page_size}")
    rng = np.random.default_rng(args.seed)
    # --prefix-cache demo: every request shares a "system prompt" head, the
    # workload prefix caching exists for (otherwise prompts are disjoint)
    shared = (list(rng.integers(0, cfg.vocab_size, size=args.max_seq // 4))
              if args.prefix_cache else [])
    tail_hi = max(5, min(32, args.max_seq - len(shared) - args.max_new))
    reqs = [Request(rid=i,
                    tokens=shared + list(rng.integers(0, cfg.vocab_size,
                                                      size=rng.integers(4, tail_hi))),
                    max_new=args.max_new,
                    temperature=args.temperature, top_k=args.top_k,
                    seed=args.seed + i)
            for i in range(args.requests)]
    t0 = now()
    done = engine.run(reqs)
    dt = now() - t0
    tok = sum(len(r.out) for r in done)
    census = engine.compilations
    print(f"served {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s), executables: prefill={census['prefill']} "
          f"decode={census['decode']} verify={census['verify']} "
          f"clear={census['clear']} "
          f"(mode={args.prefill_mode}, cache={engine.cache_kind})")
    if args.speculative:
        print(f"speculative: active={engine.speculative_active}, "
              f"draft_k={args.draft_k}, "
              f"{engine.spec_accepted}/{engine.spec_drafted} drafts accepted "
              f"(rate {engine.acceptance_rate:.2f}), "
              f"{engine.accepted_per_step:.2f} tokens/verify-step over "
              f"{engine.spec_steps} steps")
    if engine.paged:
        print(f"page pool: {engine.pcfg.n_pages} pages x "
              f"{engine.pcfg.page_size} tokens, "
              f"{engine.alloc.free_pages} free after drain")
    if args.prefix_cache:
        print(f"prefix cache: active={engine.prefix_cache_active}, "
              f"{engine.prefix_hit_pages} pages / "
              f"{engine.prefix_hit_tokens} tokens reused, "
              f"{engine.alloc.cached_free_pages} pages warm on the LRU")
    for r in done[:3]:
        f = engine.sched.fairness(r.rid)
        ttft = (r.t_first - r.t_submit) * 1e3 if r.t_first else float("nan")
        print(f"  req {r.rid}: prompt[:6]={r.tokens[:6]} -> out={r.out} "
              f"(ttft={ttft:.0f}ms, prefill_toks={f.get('prefill_tokens', 0)},"
              f" preemptions={f.get('preemptions', 0)})")
    if obs is not None:
        if args.trace_out:
            obs.write_trace(args.trace_out)
            print(f"trace: {len(obs.tracer.events)} events "
                  f"({obs.tracer.dropped} dropped) -> {args.trace_out}")
        text = obs.prometheus_text()
        if args.metrics_out:
            with open(args.metrics_out, "w", encoding="utf-8") as fh:
                fh.write(text)
            print(f"metrics: exposition -> {args.metrics_out}")
        if args.metrics:
            print("== metrics (prometheus text exposition) ==")
            print(text, end="")


if __name__ == "__main__":
    main()

"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries pure data parallelism (gradient reduction only), so pod count is
the elastic-scaling dimension (train/elastic.py).

Defined as functions so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS *before* jax initialises).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: explicit Auto axis types exist
    only on jax >= 0.5 (0.4.x meshes are implicitly auto-sharded)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """``AbstractMesh`` across jax versions: 0.4.x takes one tuple of
    (name, size) pairs, 0.5+ takes (axis_sizes, axis_names)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Tiny mesh over however many (host) devices exist — used by tests."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def make_serving_mesh(tp: int = 1, dp: int = 1):
    """Inference mesh for ``ServingEngine(mesh=...)``: ``tp``-way tensor
    parallelism on the "model" axis (attention heads / kv heads / FFN
    hidden), ``dp`` replica groups on "data".  Requires ``tp * dp`` visible
    devices — on CPU force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    n = jax.device_count()
    if tp * dp > n:
        raise ValueError(
            f"serving mesh ({dp}, {tp}) needs {tp * dp} devices but only "
            f"{n} are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp * dp}")
    return make_mesh((dp, tp), ("data", "model"))

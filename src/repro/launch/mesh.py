"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries pure data parallelism (gradient reduction only), so pod count is
the elastic-scaling dimension (train/elastic.py).

Defined as functions so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS *before* jax initialises).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Tiny mesh over however many (host) devices exist — used by tests."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

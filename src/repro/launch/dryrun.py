import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct stand-ins (zero allocation), prove the sharding is coherent,
and extract memory/cost/collective data for EXPERIMENTS.md §Dry-run/§Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh pod1
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, list_configs, supported_cells
from repro.core.famous import FamousConfig
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.parallel import sharding as shd
from repro.parallel.incontext import use_rules
from repro.roofline import analysis as roofline
from repro.roofline import hlo_cost
from repro.train import step as step_lib

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def train_cfgs(cfg):
    """Per-arch training precision policy (DESIGN.md §7 memory note)."""
    big = cfg.param_count() > 60e9
    return step_lib.TrainConfig(
        param_dtype=jnp.bfloat16 if big else jnp.float32,
        optimizer=step_lib.adamw.AdamWConfig(
            moment_dtype=jnp.bfloat16 if big else jnp.float32),
        remat=True,
    )


def lower_train(cfg, shape, mesh, rules=None, fcfg=None, tcfg=None):
    tcfg = tcfg or train_cfgs(cfg)
    fcfg = fcfg or FamousConfig(impl="xla")
    train_step = step_lib.make_train_step(cfg, fcfg, tcfg)
    state_shapes = step_lib.state_shapes(cfg, tcfg)
    state_sh = shd.tree_shardings(mesh, step_lib.state_logical_axes(cfg),
                                  rules, state_shapes)
    in_specs = specs_lib.train_input_specs(cfg, shape)
    batch_sh = {k: shd.batch_sharding(mesh, v.ndim, rules, v.shape)
                for k, v in in_specs.items()}
    metrics_sh = {k: shd.replicated(mesh)
                  for k in ("loss", "grad_norm", "lr_scale")}
    with mesh, use_rules(rules):
        jitted = jax.jit(train_step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, metrics_sh),
                         donate_argnums=0)
        return jitted.lower(state_shapes, in_specs)


def lower_serve(cfg, shape, mesh, rules=None, fcfg=None, dtype=jnp.bfloat16):
    fcfg = fcfg or FamousConfig(impl="xla")
    param_dtype = jnp.bfloat16
    spec = transformer.model_spec(cfg)
    from repro.models import module
    params_shapes = module.param_shapes(spec, param_dtype)
    params_sh = shd.tree_shardings(mesh, module.logical_axes(spec), rules,
                                   params_shapes)
    dec_specs = specs_lib.decode_input_specs(cfg, shape, dtype)
    cache_sh = shd.tree_shardings(mesh, transformer.cache_axes(cfg), rules,
                                  dec_specs["caches"])
    tok_sh = shd.batch_sharding(mesh, dec_specs["tokens"].ndim, rules,
                                dec_specs["tokens"].shape)
    len_sh = shd.batch_sharding(mesh, 1, rules, dec_specs["cache_len"].shape)
    logits_sh = shd.sharding_for_axes(
        mesh, ("batch", "vocab"), rules,
        (shape.global_batch, cfg.vocab_size))

    def serve_step(params, tokens, caches, cache_len):
        return transformer.decode_step(params, tokens, caches, cache_len,
                                       cfg, fcfg)

    with mesh, use_rules(rules):
        jitted = jax.jit(serve_step,
                         in_shardings=(params_sh, tok_sh, cache_sh, len_sh),
                         out_shardings=((logits_sh, cache_sh)),
                         donate_argnums=2)
        return jitted.lower(params_shapes, dec_specs["tokens"],
                            dec_specs["caches"], dec_specs["cache_len"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str = OUT_DIR,
             rules=None, tag: str = "", fcfg=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2" if multi_pod else "pod1"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    if shape.kind in ("train", "prefill"):
        # prefill cells lower the train-style full forward (inference-prefill
        # is the forward pass; its cost profile is what the roofline needs).
        if shape.kind == "train":
            lowered = lower_train(cfg, shape, mesh, rules, fcfg=fcfg)
        else:
            lowered = lower_prefill(cfg, shape, mesh, rules, fcfg=fcfg)
    else:
        lowered = lower_serve(cfg, shape, mesh, rules, fcfg=fcfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = hlo_cost.cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    mem_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rf = roofline.analyse(
        arch, shape_name, mesh_name, cost=cost, hlo_text=hlo, chips=chips,
        model_flops_total=roofline.model_flops(cfg, shape),
        memory_per_device=mem_per_dev)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "ok": True,
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": mem_per_dev,
            "per_device_gib": round(mem_per_dev / 2**30, 3),
            "fits_16gib": bool(mem_per_dev <= 16 * 2**30),
        },
        "cost": {k: v for k, v in cost.items()
                 if not k.startswith("utilization")},
        "roofline": rf.row(),
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def lower_prefill(cfg, shape, mesh, rules=None, fcfg=None,
                  dtype=jnp.bfloat16):
    """Inference prefill: forward to last-token logits + cache build."""
    fcfg = fcfg or FamousConfig(impl="xla")
    from repro.models import module
    spec = transformer.model_spec(cfg)
    params_shapes = module.param_shapes(spec, jnp.bfloat16)
    params_sh = shd.tree_shardings(mesh, module.logical_axes(spec), rules,
                                   params_shapes)
    in_specs = specs_lib.train_input_specs(cfg, shape, dtype)
    cache_shapes = transformer.make_caches(cfg, shape.global_batch,
                                           shape.seq_len, dtype,
                                           shapes_only=True)
    cache_sh = shd.tree_shardings(mesh, transformer.cache_axes(cfg), rules,
                                  cache_shapes)
    in_sh = shd.batch_sharding(mesh, in_specs["inputs"].ndim, rules,
                               in_specs["inputs"].shape)
    logits_sh = shd.sharding_for_axes(
        mesh, ("batch", "vocab"), rules,
        (shape.global_batch, cfg.vocab_size))

    def prefill_step(params, inputs, caches):
        return transformer.prefill(params, inputs, caches, cfg, fcfg)

    with mesh, use_rules(rules):
        jitted = jax.jit(prefill_step,
                         in_shardings=(params_sh, in_sh, cache_sh),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=2)
        return jitted.lower(params_shapes, in_specs["inputs"], cache_shapes)


def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in list_configs():
        if arch == "famous-bert":
            continue  # paper topology exercised by benchmarks, not the grid
        for s in supported_cells(arch):
            cells.append((arch, s))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(f"{a} {s}")
        return

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2" if mp else "pod1"
            fname = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"SKIP {arch} {shape} {mesh_name}")
                continue
            try:
                r = run_cell(arch, shape, mp, args.out)
                rr = r["roofline"]
                print(f"OK   {arch:22s} {shape:12s} {mesh_name} "
                      f"compile={r['t_compile_s']:>6.1f}s "
                      f"mem/dev={r['memory']['per_device_gib']:>7.3f}GiB "
                      f"dom={rr['dominant']:10s} "
                      f"frac={rr['roofline_fraction']:.3f}", flush=True)
            except Exception as e:
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"FAIL {arch} {shape} {mesh_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", *f)
        raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()

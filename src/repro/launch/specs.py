"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates.  One entry point: ``input_specs(arch, shape_name)``.

train  -> {"inputs": (B, S) int32 | (B, S, D) bf16 stub-frontend embeddings,
           "targets": (B, S) int32}
prefill-> {"inputs": ...} (same as train inputs)
decode -> {"tokens": (B,) int32 | (B, D) embeddings, "cache_len": (B,) int32}
          plus the cache tree from transformer.make_caches(shapes_only=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, get_config, SHAPES
from repro.models import transformer


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                      dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs, "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend:
        tokens = jax.ShapeDtypeStruct((B, cfg.d_model), dtype)
    else:
        tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    return {
        "tokens": tokens,
        "cache_len": jax.ShapeDtypeStruct((B,), jnp.int32),
        "caches": transformer.make_caches(cfg, B, S, dtype, shapes_only=True),
    }


def input_specs(arch: str, shape_name: str, dtype=jnp.bfloat16) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return train_input_specs(cfg, shape, dtype)
    return decode_input_specs(cfg, shape, dtype)

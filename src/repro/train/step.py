"""Training step: microbatched grad accumulation, clipping, AdamW, and the
(optional) cross-pod compressed gradient reduction.

The step is a pure function  (state, batch) -> (state, metrics)  suitable for
``jax.jit`` with explicit in/out shardings; ``state_logical_axes`` gives the
logical-axis tree the launcher maps to NamedShardings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.famous import FamousConfig
from repro.models import module, transformer
from repro.optim import adamw
from repro.train import losses


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    loss_chunk: int = 512
    z_loss: float = 0.0
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16   # bf16 activations/matmuls (mixed prec)
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule_warmup: int = 100
    schedule_total: int = 10000
    grad_compression: bool = False   # int8 EF pod-axis reduction (shard_map)


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> dict:
    spec = transformer.model_spec(cfg)
    params = module.init_params(spec, key, tcfg.param_dtype)
    return {"params": params,
            "opt": adamw.init_opt_state(params, tcfg.optimizer),
            "step": jnp.zeros((), jnp.int32)}


def state_shapes(cfg: ModelConfig, tcfg: TrainConfig) -> dict:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    spec = transformer.model_spec(cfg)
    p = module.param_shapes(spec, tcfg.param_dtype)
    mdt = tcfg.optimizer.moment_dtype
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p)
    return {"params": p,
            "opt": {"m": mom, "v": mom,
                    "count": jax.ShapeDtypeStruct((), jnp.int32)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_logical_axes(cfg: ModelConfig) -> dict:
    spec = transformer.model_spec(cfg)
    axes = module.logical_axes(spec)
    return {"params": axes, "opt": {"m": axes, "v": axes, "count": ()},
            "step": ()}


def make_train_step(cfg: ModelConfig, fcfg: FamousConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return losses.lm_loss(params, batch, cfg, fcfg, remat=tcfg.remat,
                              chunk=tcfg.loss_chunk, z_loss=tcfg.z_loss,
                              compute_dtype=tcfg.compute_dtype)

    grad_fn = jax.value_and_grad(loss_fn)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return grad_fn(params, batch)
        n = tcfg.microbatches

        def split(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, g = grad_fn(params, mb)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (loss_acc + loss, g_acc), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(acc_step, (jnp.float32(0), zeros), micro)
        inv = 1.0 / n
        return loss * inv, jax.tree_util.tree_map(lambda x: x * inv, g)

    def train_step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        lr_scale = adamw.cosine_schedule(
            state["step"], warmup=tcfg.schedule_warmup,
            total=tcfg.schedule_total)
        new_params, new_opt, om = adamw.apply_updates(
            state["params"], grads, state["opt"], tcfg.optimizer, lr_scale)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                   "lr_scale": lr_scale}
        return new_state, metrics

    return train_step

"""Checkpointing: sharded-friendly, atomic, async, restartable.

Layout:  <dir>/step_<N>/  with one ``.npy`` per leaf plus ``manifest.json``
mapping tree paths to files.  Writes go to ``<dir>/.tmp_<N>`` and are
``os.rename``d into place so a preemption mid-write never corrupts the latest
checkpoint (rename is atomic on POSIX).  ``AsyncCheckpointer`` overlaps the
host write with subsequent device steps, blocking only if a new save arrives
while the previous one is in flight (same contract as Orbax async).

On a real multi-host cluster each host writes only its addressable shards and
a barrier precedes the rename; the single-host path here is the degenerate
case of that protocol (documented for the 1000-node posture).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves], treedef


def save_checkpoint(ckpt_dir: str, step: int, state, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(state)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), np.asarray(leaf))
        manifest["leaves"].append({"path": path, "file": fname})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, state_like, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``state_like`` (shapes are validated).
    ``shardings``: optional matching tree of NamedShardings — this is also the
    elastic-resize path: restoring onto a different mesh just passes the new
    shardings."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l["file"] for l in manifest["leaves"]}
    flat, treedef = _flatten(state_like)
    shard_flat = (treedef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(flat))
    vals = []
    for (path, like), shd in zip(flat, shard_flat):
        arr = np.load(os.path.join(d, by_path[path]))
        assert tuple(arr.shape) == tuple(like.shape), (path, arr.shape, like.shape)
        vals.append(jax.device_put(arr.astype(like.dtype), shd)
                    if shd is not None else jax.numpy.asarray(arr, like.dtype))
    return jax.tree_util.tree_unflatten(treedef, vals), step


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state):
        self.wait()
        # materialise on host before returning control to the device loop
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

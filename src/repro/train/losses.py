"""Losses.  The LM loss computes logits in sequence chunks so the (B, S, V)
tensor is never materialised — at vocab 256k × 1M tokens the full logit
tensor would be ~0.5 TB in f32 globally; chunking caps the transient at
(B, chunk, V) per device (a §Perf memory-term optimisation on by default)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def _ce_from_logits(logits: jax.Array, targets: jax.Array):
    """logits: (..., V) f32; targets: (...) int32. Returns (sum_ce, sum_z2)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = lse - tgt
    return jnp.sum(ce), jnp.sum(jnp.square(lse))


def chunked_lm_loss(params, hidden: jax.Array, targets: jax.Array,
                    cfg: ModelConfig, *, chunk: int = 512,
                    z_loss: float = 0.0):
    """hidden: (B, S, D); targets: (B, S). Mean next-token CE."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: odd lengths take the unchunked path
    n = S // chunk
    hc = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)
    tc = targets.reshape(B, n, chunk).swapaxes(0, 1)

    def step(acc, xs):
        h, t = xs
        logits = transformer.logits_fn(params, h, cfg)
        ce, z2 = _ce_from_logits(logits, t)
        return (acc[0] + ce, acc[1] + z2), None

    (ce_sum, z2_sum), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                       (hc, tc))
    n_tok = B * S
    loss = ce_sum / n_tok
    if z_loss:
        loss = loss + z_loss * z2_sum / n_tok
    return loss


def lm_loss(params, batch: dict, cfg: ModelConfig, fcfg, *,
            remat: bool = True, chunk: int = 512, z_loss: float = 0.0,
            compute_dtype=None):
    hidden = transformer.forward(params, batch["inputs"], cfg, fcfg,
                                 remat=remat, return_hidden=True,
                                 compute_dtype=compute_dtype)
    return chunked_lm_loss(params, hidden, batch["targets"], cfg,
                           chunk=chunk, z_loss=z_loss)

"""Elastic scaling: resume a run on a different mesh (pod count change).

Because (a) the "pod" axis carries only data parallelism, (b) checkpoints
store full (unsharded) arrays per leaf, and (c) the data pipeline is
stateless-deterministic in (seed, step), changing the pod count is just:
build the new mesh, rebuild shardings from the same logical-axis tree, and
``restore_checkpoint`` with the new shardings.  The global batch stays fixed
(per-pod microbatch count changes), so training curves are reproducible
across resizes.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.parallel import sharding as shd
from repro.train import checkpoint as ckpt_lib


def reshard_restore(ckpt_dir: str, state_like, mesh, logical_axes_tree,
                    rules=None, step: Optional[int] = None):
    """Restore the latest checkpoint onto ``mesh`` (any pod count)."""
    axes_shardings = shd.tree_shardings(mesh, logical_axes_tree, rules)
    return ckpt_lib.restore_checkpoint(ckpt_dir, state_like, step=step,
                                       shardings=axes_shardings)


def validate_resize(old_mesh_shape: dict, new_mesh_shape: dict,
                    global_batch: int) -> list[str]:
    """Static checks before an elastic resize; returns problem list."""
    problems = []
    for ax in ("data", "model"):
        if old_mesh_shape.get(ax) != new_mesh_shape.get(ax):
            problems.append(
                f"{ax} axis changed {old_mesh_shape.get(ax)} -> "
                f"{new_mesh_shape.get(ax)}: TP/FSDP layout changes require "
                "a full reshard (supported, but not transparent)")
    dp = new_mesh_shape.get("data", 1) * new_mesh_shape.get("pod", 1)
    if global_batch % dp:
        problems.append(f"global batch {global_batch} not divisible by new "
                        f"DP degree {dp}")
    return problems

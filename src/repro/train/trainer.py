"""Trainer: the fault-tolerant outer loop.

Production behaviours implemented (and exercised by tests via injected
failures):

  * checkpoint/restart — periodic async checkpoints; on any step failure the
    trainer restores the latest checkpoint and replays from there (the data
    pipeline is stateless-deterministic, so replay is exact);
  * bounded retries — a step that keeps failing after ``max_restarts``
    escalates rather than looping forever;
  * straggler watchdog — per-step wall time is tracked against a rolling
    median; slow steps emit mitigation events (on a real cluster the runner
    would re-shard away from, or evict, repeat-offender hosts — here the
    policy hook is pluggable and unit-tested);
  * preemption hook — ``request_stop()`` (SIGTERM handler in launch/train.py)
    finishes the in-flight step, forces a final checkpoint, and exits
    cleanly.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0   # step > factor * rolling median => event
    straggler_window: int = 20


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class Trainer:
    def __init__(self, step_fn: Callable, state, batch_fn: Callable,
                 cfg: TrainerConfig, state_shardings=None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 straggler_hook: Optional[Callable[[StragglerEvent], None]] = None):
        """batch_fn(step) -> batch.  fault_hook(step) may raise to inject
        failures (tests).  straggler_hook receives StragglerEvents."""
        self.step_fn = step_fn
        self.state = state
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook
        self.straggler_hook = straggler_hook or (lambda e: None)
        self.ckpt = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
        self.metrics_log: list[dict] = []
        self.failures: list[dict] = []
        self.straggler_events: list[StragglerEvent] = []
        self.restarts = 0
        self._stop = False
        self._durations: list[float] = []

    # -- control -----------------------------------------------------------
    def request_stop(self):
        self._stop = True

    # -- helpers -----------------------------------------------------------
    def _current_step(self) -> int:
        return int(self.state["step"])

    def _save(self, step):
        self.ckpt.save(step, self.state)

    def _restore(self):
        self.ckpt.wait()  # an in-flight async save may hold the checkpoint
        restored, step = ckpt_lib.restore_checkpoint(
            self.cfg.ckpt_dir, self.state, shardings=self.state_shardings)
        self.state = restored
        return step

    def _watch_stragglers(self, step: int, dt: float):
        self._durations.append(dt)
        window = self._durations[-self.cfg.straggler_window:]
        if len(window) >= 5:
            med = statistics.median(window)
            if dt > self.cfg.straggler_factor * med:
                ev = StragglerEvent(step, dt, med)
                self.straggler_events.append(ev)
                self.straggler_hook(ev)

    # -- main loop ----------------------------------------------------------
    def run(self):
        cfg = self.cfg
        if ckpt_lib.latest_step(cfg.ckpt_dir) is not None:
            self._restore()
        if self._current_step() == 0:
            self._save(0)

        while self._current_step() < cfg.total_steps and not self._stop:
            step = self._current_step()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                t0 = time.monotonic()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics)
                dt = time.monotonic() - t0
                self._watch_stragglers(step, dt)
                self.metrics_log.append(
                    {"step": step, "dt": dt,
                     **{k: float(v) for k, v in metrics.items()}})
            except (FloatingPointError, RuntimeError, ValueError) as e:
                self.restarts += 1
                self.failures.append({"step": step, "error": repr(e)})
                if self.restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"step {step} failed {self.restarts} times") from e
                self._restore()
                continue
            new_step = self._current_step()
            if new_step % cfg.ckpt_every == 0 or new_step >= cfg.total_steps:
                self._save(new_step)
        self.ckpt.wait()
        if self._stop:  # preemption: persist progress before exit
            ckpt_lib.save_checkpoint(cfg.ckpt_dir, self._current_step(),
                                     self.state, cfg.keep)
        return self.state


class InjectedFault(RuntimeError):
    """Raised by test fault hooks to simulate node failure."""

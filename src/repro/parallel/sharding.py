"""Logical-axis sharding: map model-declared axis names onto mesh axes.

Parallelism encoded by the default rules (DESIGN.md §4):
  * DP   — "batch" over ("pod", "data"); the pod axis carries only data
           parallelism + gradient reduction, so pod count scales elastically.
  * TP   — heads / kv_heads / mlp / experts / vocab over "model" (Megatron).
  * FSDP — the "embed" dim of weights over "data" (ZeRO-3; XLA all-gathers
           one scanned layer at a time).
  * EP   — "experts" over "model" (expert parallelism; all-to-all routing).

Rules are a plain list so the §Perf hillclimb can swap them per-arch.
"""
from __future__ import annotations

import warnings
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = dict[str, Any]

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    # attention-region batch: archs whose head count does not divide the
    # model axis (qwen2 28H, llava 56H, recurrentgemma 10H) reshard the
    # attention block to pure data parallelism over ALL mesh axes instead of
    # replicating head compute 16x (see EXPERIMENTS.md §Perf, iteration Q1).
    "batch_attn": ("pod", "data", "model"),
    # context parallelism for the same fallback: query-sequence dim over the
    # model axis (K/V replicated there; dK/dV all-reduce back) — keeps all
    # 512 chips busy when batch alone cannot cover them (§Perf iteration Q2).
    "seq_tp": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "mlp": "model",
    "experts": "model",
    "expert_ff": "data",
    "embed": "data",        # FSDP / ZeRO-3
    "head_dim": None,
    "layers": None,
    "seq": None,
}

# Pure tensor-parallel rules (no FSDP) — small models where the all-gather
# cost of ZeRO outweighs its memory win; a §Perf lever.
TP_ONLY_RULES: Rules = {**DEFAULT_RULES, "embed": None}

# Serving tensor parallelism (ServingEngine(mesh=...)): attention heads /
# kv heads / FFN hidden shard over "model"; everything the host bookkeeping
# loop touches stays replicated — the embedding table and LM head ("vocab"
# unsharded, so logits come back replicated and sampling / argmax / the
# one device->host sync per step are unchanged), and no FSDP (weights are
# read-only at inference; re-gathering them every step would swamp the
# step time).  The only collectives inside the hot executables are the
# attention-output and FFN-down all-reduces GSPMD inserts at the two
# row-parallel matmuls (wo, w_down).
SERVE_TP_RULES: Rules = {**TP_ONLY_RULES, "vocab": None}

# ZeRO-3 + sequence sharding, no tensor parallelism (§Perf iteration Q7):
# weights fully sharded over every mesh axis on their "embed" dim and
# re-gathered per layer; tokens sharded (batch × seq); FFN/attention run with
# zero per-layer all-reduces.  Wins for ≤~15B models where TP activation
# all-reduces dominate (qwen2/deepseek at 1M-token steps); loses for ≥100B
# where regathering the weights three times a step would swamp the ICI.
ZERO_SEQ_RULES: Rules = {
    **DEFAULT_RULES,
    "embed": ("pod", "data", "model"),
    "heads": None, "kv_heads": None, "heads_flat": None,
    "mlp": None, "experts": None, "vocab": "model",
}


def _present(mesh: Mesh, axis) -> Any:
    """Drop mesh axes the current mesh does not have (single-pod has no
    "pod"); collapse empty tuples to None."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


# (logical axis, dim size, resolved mesh axes) combos already warned about:
# a 64-layer cache tree resolves the same non-divisible kv_heads dim once
# per leaf, and the engine re-resolves per engine — one warning is enough.
_REPLICATE_WARNED: set = set()


def _warn_replicated(name: str, dim: int, axis, size: int) -> None:
    key = (name, dim, axis, size)
    if key in _REPLICATE_WARNED:
        return
    _REPLICATE_WARNED.add(key)
    warnings.warn(
        f"logical axis {name!r} (dim {dim}) is not divisible by mesh "
        f"axis {axis!r} ({size}-way); replicating this dim instead of "
        f"letting XLA reject the sharding at placement time",
        RuntimeWarning, stacklevel=3)


def spec_for_axes(mesh: Mesh, axes: Sequence[Optional[str]],
                  rules: Rules | None = None,
                  shape: Sequence[int] | None = None) -> P:
    """Resolve logical axes to a PartitionSpec.

    When ``shape`` is given, dims that are not divisible by their mesh-axis
    product fall back gracefully (try shorter prefixes of a tuple rule, then
    replicate, with a single :class:`RuntimeWarning` per distinct fallback)
    — pjit in_shardings demand exact divisibility, and several assigned
    configs have head counts (10/28/56), kv-head counts (2/6) or vocab
    (504) that do not divide a 16-way (or even 4-way) model axis.  The
    §Perf log tracks what this costs.
    """
    rules = rules or DEFAULT_RULES
    parts = []
    used: set = set()

    def axis_size(m) -> int:
        if m is None:
            return 1
        if isinstance(m, tuple):
            out = 1
            for a in m:
                out *= mesh.shape[a]
            return out
        return mesh.shape[m]

    def usable(m):
        """A mesh axis may appear only once in a PartitionSpec."""
        if m is None:
            return None
        if isinstance(m, tuple):
            kept = tuple(a for a in m if a not in used)
            for a in kept:
                used.add(a)
            return kept if kept else None
        if m in used:
            return None
        used.add(m)
        return m

    for i, name in enumerate(axes):
        m = _present(mesh, rules.get(name)) if name else None
        if m is not None and shape is not None:
            ruled, ruled_size = m, axis_size(m)
            cands = [m]
            if isinstance(m, tuple):  # try shorter prefixes before giving up
                cands = [m[:k] for k in range(len(m), 0, -1)]
            m = None
            for c in cands:
                c = c if isinstance(c, tuple) else c
                if shape[i] % axis_size(c) == 0:
                    m = c if not isinstance(c, tuple) or len(c) > 1 else c[0]
                    break
            if m is None:
                _warn_replicated(name, shape[i], ruled, ruled_size)
        parts.append(usable(m))
    return P(*parts)


def sharding_for_axes(mesh: Mesh, axes: Sequence[Optional[str]],
                      rules: Rules | None = None,
                      shape: Sequence[int] | None = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for_axes(mesh, axes, rules, shape))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(mesh: Mesh, axes_tree, rules: Rules | None = None,
                   shapes_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings.  ``shapes_tree``
    (matching tree of ShapeDtypeStructs/arrays) enables divisibility-aware
    fallback."""
    if shapes_tree is None:
        return jax.tree_util.tree_map(
            lambda axes: sharding_for_axes(mesh, axes, rules),
            axes_tree, is_leaf=_is_axes_leaf)
    flat_axes, treedef = jax.tree_util.tree_flatten(axes_tree,
                                                    is_leaf=_is_axes_leaf)
    flat_shapes = treedef.flatten_up_to(shapes_tree)
    out = [sharding_for_axes(mesh, a, rules, tuple(s.shape))
           for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_sharding(mesh: Mesh, ndim: int, rules: Rules | None = None,
                   shape: Sequence[int] | None = None) -> NamedSharding:
    """Batch-leading activation sharding: (batch, ...) -> dp axes on dim 0."""
    axes = ["batch"] + [None] * (ndim - 1)
    return sharding_for_axes(mesh, axes, rules, shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out

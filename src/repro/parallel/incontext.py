"""In-graph sharding constraints from logical axis names.

``constrain(x, axes)`` applies ``with_sharding_constraint`` using the ambient
mesh (the ``with mesh:`` context the launcher jits under) and the same
divisibility-aware rule resolution as parallel/sharding.py.  No-op when no
mesh is active (CPU smoke tests) so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

from repro.parallel import sharding as shd

# Active rule set for in-graph constraints; launchers that lower with
# non-default rules set this so model-internal constraints agree.
_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules):
    tok = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(tok)


def _ambient_mesh():
    try:  # explicit-mesh contexts (jax >= 0.7)
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:  # classic `with mesh:` context
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, axes, rules=None):
    """x: array; axes: logical axis name per dim (None = unsharded)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    rules = rules or _ACTIVE_RULES.get()
    spec = shd.spec_for_axes(mesh, axes, rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _reshard2(x, fwd_axes, bwd_axes, rules):
    return constrain(x, fwd_axes, rules)


def _reshard2_fwd(x, fwd_axes, bwd_axes, rules):
    return constrain(x, fwd_axes, rules), None


def _reshard2_bwd(fwd_axes, bwd_axes, rules, _, g):
    return (constrain(g, bwd_axes, rules),)


_reshard2.defvjp(_reshard2_fwd, _reshard2_bwd)


def reshard_fwd_bwd(x, fwd_axes, bwd_axes, rules=None):
    """Constrain the primal to ``fwd_axes`` and its cotangent to
    ``bwd_axes``.  Used where the value and its gradient want different
    layouts (e.g. K/V replicated across "model" for context-parallel
    attention, but dK/dV reduce-scattered to sequence shards)."""
    return _reshard2(x, tuple(fwd_axes), tuple(bwd_axes), rules)


def heads_divide_model(num_heads: int) -> bool:
    """True when head-TP is exact on the ambient mesh (or no mesh active)."""
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return True
    return num_heads % mesh.shape["model"] == 0


def constrain_residual(x, num_heads: int, rules=None):
    """Sequence-parallel residual stream for non-divisible-head archs: the
    hidden state lives (batch, seq@model, d) between blocks, so norms/FFN
    run 512-way and the attention q path needs no resharding at all (§Perf
    iteration Q3)."""
    if heads_divide_model(num_heads):
        return x
    return constrain(x, ("batch", "seq_tp", None), rules)


def constrain_attn_activations(q, k, v, num_heads: int, rules=None):
    """Pick the attention-region layout: head-TP when heads divide the model
    axis (no resharding, projections emit model-sharded heads); otherwise
    full-DP over every mesh axis (one all-to-all in, one out — 16x cheaper
    than replicated head compute)."""
    if heads_divide_model(num_heads):
        q = constrain(q, ("batch", None, "heads", None), rules)
        k = constrain(k, ("batch", None, "kv_heads", None), rules)
        v = constrain(v, ("batch", None, "kv_heads", None), rules)
        return q, k, v
    # Context parallelism: batch over dp, query sequence over "model"
    # (K/V replicated on "model"; GSPMD reduces dK/dV over the seq shards).
    # NOTE (§Perf iteration Q6, refuted): forcing the dK/dV cotangents to
    # reduce-scatter to seq shards via reshard_fwd_bwd DOUBLED collective
    # bytes (XLA all-reduced first, then resharded) — keep the default.
    q = constrain(q, ("batch", "seq_tp", None, None), rules)
    k = constrain(k, ("batch", None, None, None), rules)
    v = constrain(v, ("batch", None, None, None), rules)
    return q, k, v

"""Runtime programmability — paper §IV-C mapped to TPU.

FAMOUS synthesises once (fixing TS and resource maxima) and then serves any
(heads, d_model, sequence length) at or below the synthesis-time maxima by
reprogramming loop bounds from the MicroBlaze at runtime — no re-synthesis.

The TPU analogue of "synthesis" is XLA compilation.  Two mechanisms:

* :class:`FlexibleAttention` — ONE compiled executable at the maxima.  Smaller
  topologies are zero-padded to the maxima and masked; the actual head count,
  head dim and sequence length arrive as *runtime operands* (like the µB
  control words), so no recompilation ever happens.  Padded heads are the
  idle PE groups of tests #2–#3; padded sequence = masked keys; the softmax
  scale uses the actual head dim (tests #4–#5's d_model sweep).

* :class:`BucketCache` — a small executable cache keyed by rounded-up shape
  buckets, trading a handful of compilations for zero padding waste.  The
  serving engine uses this; the single-program mode is the paper-faithful
  extreme point (bucket count = 1).
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import famous


class FlexibleAttention:
    """One executable, every topology ≤ (max_heads, max_seq, max_head_dim)."""

    def __init__(self, max_heads: int, max_seq: int, max_head_dim: int,
                 cfg: famous.FamousConfig | None = None, causal: bool = True):
        self.max_heads = max_heads
        self.max_seq = max_seq
        self.max_head_dim = max_head_dim
        self.cfg = cfg or famous.FamousConfig()
        self.causal = causal
        self._fn = jax.jit(self._padded_attention)
        self.compilations = 0

    def _padded_attention(self, q, k, v, seq_len, head_dim):
        # q,k,v: (B, max_seq, max_heads, max_head_dim) zero-padded.
        # Tracing happens exactly once per compiled executable, so this
        # python-side counter counts compilations; the paper-faithful
        # single-program claim is that it stays at 1 across topologies.
        self.compilations += 1
        scale = 1.0 / jnp.sqrt(head_dim.astype(jnp.float32))
        kpos = jnp.arange(self.max_seq)
        qpos = jnp.arange(self.max_seq)
        ok = (kpos < seq_len)[None, :]                      # key padding mask
        if self.causal:
            ok = ok & (kpos[None, :] <= qpos[:, None])
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        big_neg = jnp.finfo(jnp.float32).min
        s = jnp.where(ok[None, None], s, big_neg)           # finite: padded q
        p = jax.nn.softmax(s, axis=-1)                      # rows stay NaN-free
        return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)

    def __call__(self, q, k, v):
        """q,k,v: (B, S, H, dh) with S ≤ max_seq, H ≤ max_heads, dh ≤ max."""
        B, S, H, dh = q.shape
        assert S <= self.max_seq and H <= self.max_heads and dh <= self.max_head_dim, (
            f"topology {(S, H, dh)} exceeds synthesis-time maxima "
            f"{(self.max_seq, self.max_heads, self.max_head_dim)}")

        def pad(x):
            return jnp.pad(x, ((0, 0), (0, self.max_seq - S),
                               (0, self.max_heads - H),
                               (0, self.max_head_dim - dh)))

        out = self._fn(pad(q), pad(k), pad(v), jnp.int32(S), jnp.int32(dh))
        return out[:, :S, :H, :dh]


class BucketCache:
    """Shape-bucketed executable cache: compile per bucket, pad within."""

    def __init__(self, fn: Callable, bucket_fn: Callable[[int], int] | None = None):
        self._fn = fn
        self._cache: dict = {}
        self._bucket = bucket_fn or next_pow2
        self.hits = 0
        self.misses = 0

    def get(self, seq: int):
        b = self._bucket(seq)
        if b not in self._cache:
            self.misses += 1
            self._cache[b] = jax.jit(functools.partial(self._fn, bucket=b),
                                     static_argnames=())
        else:
            self.hits += 1
        return self._cache[b], b

    def __len__(self):
        return len(self._cache)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (next_pow2(1) == 1)."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()

"""8-bit quantization — the TPU analogue of FAMOUS's 8-bit fixed point.

FAMOUS quantises inputs/weights to 8-bit fixed point so each DSP48 performs
int8 MACs.  On TPU v5e the analogue is the int8 MXU path (394 TOPS int8 vs
197 TFLOP/s bf16): symmetric per-channel scales, int8×int8→int32 dot,
dequantised by the product of scales.  ``int8_einsum`` is used by the
``quant="int8"`` FAMOUS config and by the int8 Pallas projection kernel's
reference oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, axis: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization along ``axis`` (the contraction dim).

    Returns (q_int8, scale) with x ≈ q * scale; scale has size-1 ``axis``.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_einsum(spec: str, x: jax.Array, w: jax.Array,
                out_dtype=None) -> jax.Array:
    """einsum with both operands quantised to int8 over their contraction dims.

    Restriction: the contraction must be a single dim that is the last dim of
    ``x`` and the first dim of ``w`` (the shapes FAMOUS uses: activations ×
    weights).

    Accumulation contract: the int8×int8 dot accumulates in **int32** (never
    int8 — no wraparound regardless of contraction length), then the int32
    accumulator is dequantised in **fp32** by the outer product of the two
    per-channel scales — exactly the fixed-point→float convert step of the
    FPGA pipeline.  Only the final cast narrows: the result is
    ``out_dtype`` when given, else ``x.dtype``.  With bf16 inputs the
    intermediate precision is therefore *higher* than a plain bf16 einsum
    (int32/fp32 accumulate, one rounding at the end); pass
    ``out_dtype=jnp.float32`` to keep the full accumulator precision.
    """
    lhs, rest = spec.split(",")
    rhs, out = rest.split("->")
    c = lhs[-1]
    assert rhs[0] == c and c not in out, f"unsupported int8 einsum {spec}"
    xq, xs = quantize(x, axis=-1)              # xs: x.shape[:-1] + (1,)
    wq, ws = quantize(w, axis=0)               # ws: (1,) + w.shape[1:]
    acc = jnp.einsum(spec, xq.astype(jnp.int32), wq.astype(jnp.int32))
    # scale broadcast: x scales cover the batch/seq dims of out, w scales the rest
    x_bcast = xs.reshape(xs.shape[:-1] + (1,) * (len(w.shape) - 1))
    out_f = acc.astype(jnp.float32) * x_bcast * ws.reshape((1,) * (len(x.shape) - 1) + w.shape[1:])
    return out_f.astype(x.dtype if out_dtype is None else out_dtype)

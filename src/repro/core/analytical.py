"""Analytical latency model — the paper's §VII (Eq. 3–14) adapted to TPU v5e.

FAMOUS predicts per-module latency with the pipelined-loop model

    PLL = (TC − 1) · II + Pipeline_Depth          (Eq. 3)
    TL  = PLL · outer trip count                  (Eq. 4)

On a TPU the same structure holds for a ``pallas_call`` grid: the grid is the
trip count, the initiation interval II of the software-pipelined grid loop is
``max(tile_compute_time, tile_DMA_time)`` (compute/DMA overlap), and the
pipeline depth is the first tile's DMA fill.  The per-module equations (Eq.
5–12: LI/LB/LIA/LWA for loads, SA/S/SV for the three PMs) become per-module
(FLOPs, HBM bytes, VMEM working set) terms.

The model serves the same two purposes as in the paper:
  1. predict latency before "synthesis" (here: before compiling / on CPU-only
     hosts where wall-clock TPU time cannot be measured), validated against
     XLA ``cost_analysis()`` in ``benchmarks/analytical_validation.py``;
  2. choose the tile size: ``autotune_tiles`` rejects tilings whose working
     set exceeds VMEM and picks the II-minimising (block_q, block_k, block_d)
     — replacing the paper's 36-hour trial synthesis loop per TS.
"""
from __future__ import annotations

import dataclasses
import itertools
import math


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    name: str = "tpu-v5e"
    peak_bf16: float = 197e12       # FLOP/s
    peak_int8: float = 394e12       # OP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link (per direction)
    hbm_bytes: float = 16 * 2**30
    vmem_bytes: float = 64 * 2**20  # usable budget (half of 128 MiB)
    mxu: int = 128                  # systolic dim; tiles should align to this
    dma_latency: float = 1e-6       # per-transfer fixed cost (PD analogue)


V5E = TpuSpec()


@dataclasses.dataclass
class ModuleLatency:
    """One FAMOUS processing module's predicted cost."""

    name: str
    flops: float
    hbm_bytes: float
    vmem_bytes: float       # per-step working set (must fit VMEM)
    steps: int              # trip count TC (number of tiles / grid size)

    t_compute: float = 0.0
    t_memory: float = 0.0
    ii: float = 0.0         # initiation interval
    t_total: float = 0.0    # (TC-1)*II + PD

    def finalize(self, hw: TpuSpec, peak: float) -> "ModuleLatency":
        self.t_compute = self.flops / peak
        self.t_memory = self.hbm_bytes / hw.hbm_bw
        per_step_c = self.t_compute / max(self.steps, 1)
        per_step_m = self.t_memory / max(self.steps, 1)
        self.ii = max(per_step_c, per_step_m)
        pd = per_step_m + hw.dma_latency  # first-tile DMA fill
        self.t_total = max(self.steps - 1, 0) * self.ii + pd + per_step_c
        return self


@dataclasses.dataclass
class MhaLatency:
    modules: list[ModuleLatency]

    @property
    def total(self) -> float:            # Eq. 13
        return sum(m.t_total for m in self.modules)

    @property
    def flops(self) -> float:
        return sum(m.flops for m in self.modules)

    @property
    def hbm_bytes(self) -> float:
        return sum(m.hbm_bytes for m in self.modules)

    def gops(self) -> float:
        """Throughput in GOPS as the paper reports (ops = 2*MACs)."""
        return self.flops / self.total / 1e9

    def table(self) -> str:
        rows = [f"{'module':<10}{'steps':>7}{'GFLOP':>10}{'MB':>10}"
                f"{'II(us)':>10}{'t(us)':>10}"]
        for m in self.modules:
            rows.append(
                f"{m.name:<10}{m.steps:>7}{m.flops/1e9:>10.3f}"
                f"{m.hbm_bytes/1e6:>10.3f}{m.ii*1e6:>10.3f}{m.t_total*1e6:>10.2f}")
        rows.append(f"{'TOTAL':<10}{'':>7}{self.flops/1e9:>10.3f}"
                    f"{self.hbm_bytes/1e6:>10.3f}{'':>10}{self.total*1e6:>10.2f}")
        return "\n".join(rows)


def mha_latency(*, batch: int, seq: int, heads: int, kv_heads: int,
                head_dim: int, d_model: int, tile_q: int = 512,
                tile_k: int = 512, tile_d: int = 512, dtype_bytes: int = 2,
                kv_seq: int | None = None, hw: TpuSpec = V5E,
                quant: str = "none") -> MhaLatency:
    """Predict FAMOUS MHA latency on TPU for one (B, S, H, dh) problem.

    Mirrors Eq. 5–13: module terms for loading inputs/weights (folded into
    each module's HBM bytes — on TPU loads are the DMA half of the pipeline,
    not separate phases) and the three PMs.
    """
    kv_seq = kv_seq or seq
    peak = hw.peak_int8 if quant == "int8" else hw.peak_bf16
    in_bytes = 1 if quant == "int8" else dtype_bytes
    tile_q = min(tile_q, seq)
    tile_k = min(tile_k, kv_seq)
    tile_d = min(tile_d, d_model)
    proj = heads * head_dim

    # --- QKV_PM (Alg. 1): X (B,S,D) x W (D, 3*proj_q + 2 uses kv) ----------
    # Tiling-aware traffic (the mechanism behind Table I tests #9-#10):
    # with an output-stationary (tile_q x tile_f) accumulation over TS-sized
    # reduction tiles, X is re-read once per output-column block and W once
    # per token block — smaller tiles mean more reloads, exactly the FPGA's
    # "each tile loaded (d_model/TS) times".
    kv_proj = kv_heads * head_dim
    w_cols = proj + 2 * kv_proj
    tile_f = min(tile_k, w_cols)
    flops = 2.0 * batch * seq * d_model * w_cols
    n_tiles_d = math.ceil(d_model / tile_d)                     # TS loop
    n_tiles_f = math.ceil(w_cols / tile_f)
    n_tiles_t = math.ceil(batch * seq / tile_q)
    hbm = (in_bytes * batch * seq * d_model * n_tiles_f         # X reloads
           + in_bytes * d_model * w_cols * n_tiles_t            # W reloads
           + dtype_bytes * batch * seq * w_cols)                # QKV out once
    vmem = in_bytes * (tile_q * tile_d + tile_d * tile_f) \
        + 4 * tile_q * tile_f                                   # f32 acc
    steps = n_tiles_t * n_tiles_f * n_tiles_d
    qkv = ModuleLatency("QKV_PM", flops, hbm, vmem, steps).finalize(hw, peak)

    # --- QK_PM (Alg. 2) + softmax ------------------------------------------
    # Q tile resident; K streams once per q block (flash ordering).
    n_q = max(1, seq // tile_q)
    n_k = max(1, kv_seq // tile_k)
    flops = 2.0 * batch * heads * seq * kv_seq * head_dim
    softmax_flops = 6.0 * batch * heads * seq * kv_seq          # exp/sum VPU
    hbm = dtype_bytes * batch * (seq * proj                     # Q once
                                 + kv_seq * kv_proj * n_q)      # K per q-block
    vmem = dtype_bytes * (tile_q * head_dim + tile_k * head_dim) \
        + 4 * tile_q * tile_k
    steps = n_q * n_k * batch * heads
    qk = ModuleLatency("QK_PM", flops + softmax_flops, hbm, vmem,
                       steps).finalize(hw, peak)

    # --- SV_PM (Alg. 3) ------------------------------------------------------
    flops = 2.0 * batch * heads * seq * kv_seq * head_dim
    hbm = dtype_bytes * batch * (kv_seq * kv_proj * n_q          # V per q-blk
                                 + seq * proj)                   # O out
    vmem = dtype_bytes * (tile_q * tile_k + tile_k * head_dim) \
        + 4 * tile_q * head_dim
    steps = n_q * n_k * batch * heads
    sv = ModuleLatency("SV_PM", flops, hbm, vmem, steps).finalize(hw, peak)

    return MhaLatency([qkv, qk, sv])


def fits_vmem(lat: MhaLatency, hw: TpuSpec = V5E) -> bool:
    # double-buffered DMA: 2x the working set must fit
    return all(2 * m.vmem_bytes <= hw.vmem_bytes for m in lat.modules)


def autotune_tiles(*, batch: int, seq: int, heads: int, kv_heads: int,
                   head_dim: int, d_model: int, dtype_bytes: int = 2,
                   hw: TpuSpec = V5E, quant: str = "none",
                   candidates=(128, 256, 512, 1024, 2048)) -> dict:
    """Pick (tile_q, tile_k, tile_d) minimising predicted total latency under
    the VMEM constraint — the paper's TS sweep without the 36 h synthesis."""
    best = None
    for tq, tk, td in itertools.product(candidates, repeat=3):
        if tq % hw.mxu or tk % hw.mxu or td % hw.mxu:
            continue
        lat = mha_latency(batch=batch, seq=seq, heads=heads,
                          kv_heads=kv_heads, head_dim=head_dim,
                          d_model=d_model, tile_q=tq, tile_k=tk, tile_d=td,
                          dtype_bytes=dtype_bytes, hw=hw, quant=quant)
        if not fits_vmem(lat, hw):
            continue
        if best is None or lat.total < best[0]:
            best = (lat.total, dict(tile_q=tq, tile_k=tk, tile_d=td), lat)
    assert best is not None, "no feasible tiling"
    return {"tiles": best[1], "latency": best[2]}


def paper_gops(*, seq: int, d_model: int, heads: int) -> float:
    """Operation count (GOP) as the paper counts it: QKV + QK + SV MACs*2."""
    dh = d_model // heads
    qkv = 2 * seq * d_model * 3 * d_model
    qk = 2 * heads * seq * seq * dh
    sv = 2 * heads * seq * seq * dh
    return (qkv + qk + sv) / 1e9

"""FAMOUS core — the paper's contribution as composable JAX modules."""
from repro.core.famous import (  # noqa: F401
    FamousConfig,
    attention,
    attention_reference,
    attention_xla,
    decode_attention,
    mha_block,
    qkv_projection,
    qkv_projection_reference,
    qkv_projection_xla,
)

"""FAMOUS core: flexible, tiled, dense multi-head attention (the paper's
contribution), adapted from UltraScale+ FPGAs to TPU.

The paper decomposes MHA into three processing modules —

  * ``QKV_PM`` :  Q/K/V = X·W{q,k,v} + B{q,k,v}   (Algorithm 1, column-tiled)
  * ``QK_PM``  :  S = softmax(Q·Kᵀ / √d_k)        (Algorithm 2 + LUT softmax)
  * ``SV_PM``  :  A = S·V                          (Algorithm 3)

— each with its own PE-array geometry, with the weight matrices tiled along
the *reduction* dimension in tiles of size ``TS`` so one tile fits in BRAM.

This module provides three interchangeable implementations of the same math:

  impl="reference"  paper-faithful: explicit TS-tile loop with partial-sum
                    accumulation (Alg 1) and a fully materialised S matrix
                    (the FPGA stores S in BRAM; feasible at the paper's SL=64).
                    This is the reproduction baseline.
  impl="xla"        TPU-native XLA path: fused projections and an *online*
                    (running max/sum) softmax over key tiles — identical math,
                    same tiling structure, but S is never materialised.  Used
                    by training, serving and the multi-pod dry-run.
  impl="pallas"     hand-written Pallas TPU kernels (kernels/qkv, kernels/
                    attention) with BlockSpec VMEM tiling — the TS analogue is
                    the (block_q, block_k, block_d) triple.  Trainable: the
                    attention kernel carries a flash custom-VJP whose dq and
                    dk/dv passes are themselves Pallas kernels (blockwise
                    recompute from the saved LSE, mirroring _flash_bwd_rule
                    below), and the QKV matmul kernel differentiates through
                    itself.  Validated in interpret mode on CPU; selected on
                    real TPU backends.

GQA extends the paper (which is pure MHA): K/V heads are broadcast to query
heads inside the QK/SV modules, mirroring how FAMOUS shares K BRAMs across PE
groups.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant as quant_lib


@dataclasses.dataclass(frozen=True)
class FamousConfig:
    """Tiling + dispatch knobs (the TS analogue and runtime maxima)."""

    tile_d: int = 512       # TS for the QKV_PM reduction dim (d_model)
    tile_q: int = 512       # query-tile rows held on-chip in QK/SV modules
    tile_k: int = 512       # key-tile columns streamed through QK/SV modules
    impl: str = "xla"       # reference | xla | pallas
    quant: str = "none"     # none | int8  (paper uses 8-bit fixed point)
    # Runtime-programmable maxima (paper §IV-C: h/d_model/SL adjustable below
    # synthesis-time maxima without re-synthesis).
    max_heads: int = 0
    max_seq: int = 0
    max_d_model: int = 0


# ---------------------------------------------------------------------------
# QKV_PM — Algorithm 1
# ---------------------------------------------------------------------------

def qkv_projection_reference(x, wq, wk, wv, bq=None, bk=None, bv=None, *,
                             tile_d: int = 64):
    """Paper-faithful Algorithm 1: column-tiled projection with accumulation.

    x : (..., S, D); w* : (D, H, dh) — tiled along D (the reduction dim, the
    one FAMOUS tiles since "the first dimension is already reduced by the
    number of heads").  Each iteration loads one (TS,)-slice of x and one
    (TS, H, dh) tile of each weight and accumulates partial products, exactly
    as the BRAM tiles are swapped and partial sums accumulated on the FPGA.
    """
    d = x.shape[-1]
    tile_d = min(tile_d, d)
    assert d % tile_d == 0, (d, tile_d)
    n_tiles = d // tile_d

    def one(w):
        acc = jnp.zeros(x.shape[:-1] + w.shape[1:], jnp.float32)
        for t in range(n_tiles):  # the (d_model / TS) BRAM-reload iterations
            xs = jax.lax.dynamic_slice_in_dim(x, t * tile_d, tile_d, axis=-1)
            ws = jax.lax.dynamic_slice_in_dim(w, t * tile_d, tile_d, axis=0)
            acc = acc + jnp.einsum(
                "...sd,dhe->...she", xs.astype(jnp.float32), ws.astype(jnp.float32)
            )
        return acc

    q, k, v = one(wq), one(wk), one(wv)
    # Bias load is overlapped with compute on the FPGA; added at the end.
    if bq is not None:
        q, k, v = q + bq, k + bk, v + bv
    return q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)


def qkv_projection_xla(x, wq, wk, wv, bq=None, bk=None, bv=None, *,
                       quantized: bool = False):
    """Fused XLA projection (one read of x feeds three matmuls, like the
    shared X BRAM in QKV_PM).  Optional int8 path = 8-bit fixed point."""
    if quantized:
        q = quant_lib.int8_einsum("...sd,dhe->...she", x, wq)
        k = quant_lib.int8_einsum("...sd,dhe->...she", x, wk)
        v = quant_lib.int8_einsum("...sd,dhe->...she", x, wv)
    else:
        w = jnp.concatenate(
            [wq.reshape(wq.shape[0], -1), wk.reshape(wk.shape[0], -1),
             wv.reshape(wv.shape[0], -1)], axis=-1)
        qkv = jnp.einsum("...sd,df->...sf", x, w.astype(x.dtype))
        nq = wq.shape[1] * wq.shape[2]
        nk = wk.shape[1] * wk.shape[2]
        q = qkv[..., :nq].reshape(x.shape[:-1] + wq.shape[1:])
        k = qkv[..., nq:nq + nk].reshape(x.shape[:-1] + wk.shape[1:])
        v = qkv[..., nq + nk:].reshape(x.shape[:-1] + wv.shape[1:])
    if bq is not None:
        q, k, v = q + bq.astype(q.dtype), k + bk.astype(k.dtype), v + bv.astype(v.dtype)
    return q, k, v


def qkv_projection(x, wq, wk, wv, bq=None, bk=None, bv=None, *,
                   cfg: FamousConfig = FamousConfig()):
    if cfg.impl == "reference":
        return qkv_projection_reference(x, wq, wk, wv, bq, bk, bv,
                                        tile_d=cfg.tile_d)
    if cfg.impl == "pallas":
        from repro.kernels.qkv import ops as qkv_ops
        return qkv_ops.qkv_projection(x, wq, wk, wv, bq, bk, bv,
                                      tile_d=cfg.tile_d, quant=cfg.quant)
    return qkv_projection_xla(x, wq, wk, wv, bq, bk, bv,
                              quantized=cfg.quant == "int8")


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, dtype=jnp.float32):
    """Additive mask bias (0 / -inf) for (len(q_pos), len(k_pos))."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(dtype)


def _broadcast_kv(x, num_q_heads):
    """GQA: repeat kv heads to match query heads. x: (B, S, KV, dh)."""
    kv = x.shape[-2]
    if kv == num_q_heads:
        return x
    return jnp.repeat(x, num_q_heads // kv, axis=-2)


# ---------------------------------------------------------------------------
# QK_PM + softmax + SV_PM — Algorithms 2 & 3
# ---------------------------------------------------------------------------

def attention_reference(q, k, v, *, causal=True, window=0, scale=None,
                        q_offset=0):
    """Paper-faithful QK_PM/SV_PM: materialise S (the FPGA keeps S in BRAM),
    full softmax, then S·V.  Fine at the paper's SL=64; the baseline oracle."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    k = _broadcast_kv(k, H)
    v = _broadcast_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)[None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _flash_forward(q, k, v, *, causal, window, scale, q_offset, block_k):
    """Online-softmax forward over key tiles. q,k,v: (B,S,H,dh), kv already
    broadcast to H heads. Returns (out (B,Sq,H,dh), lse (B,H,Sq))."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    nkb = Skv // block_k
    q_pos = q_offset + jnp.arange(Sq)
    # §Perf C1 (REFUTED): casting P blocks to bf16 before the PV dot
    # materialised both the f32 and bf16 copies in the XLA path (+26% HBM
    # traffic); P stays f32 here — the VMEM-resident Pallas kernel is the
    # path that truly removes this traffic on TPU.
    p_dtype = jnp.float32

    kb = k.reshape(B, nkb, block_k, H, dh).swapaxes(0, 1)
    vb = v.reshape(B, nkb, block_k, H, dh).swapaxes(0, 1)

    def step(carry, blk):
        acc, m, l = carry
        kt, vt, kb_idx = blk
        k_pos = kb_idx * block_k + jnp.arange(block_k)
        # C2: native-dtype QK dot with f32 accumulation — bf16 operands hit
        # the MXU fast path and halve the q/k HBM reads vs upcast-first.
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kt,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)[None, None]
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use where
        safe_m = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(jnp.where(jnp.isinf(s), -jnp.inf, s - safe_m[..., None]))
        corr = jnp.where(jnp.isinf(m), jnp.zeros_like(m), jnp.exp(m - safe_m))
        l = l * corr + p.sum(-1)
        # probabilities cross HBM in p_dtype (§Perf iteration C1): the
        # (bq, bk) P block is the dominant HBM traffic of the XLA flash path
        # at 32k; the row stats (m, l) and accumulator stay f32.
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(p_dtype),
            vt.astype(p_dtype)).astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (kb, vb, jnp.arange(nkb)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).swapaxes(1, 2).astype(q.dtype)
    lse = jnp.where(jnp.isinf(m), m, m + jnp.log(l_safe))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention(q, k, v, causal, window, scale, q_offset, block_k):
    out, _ = _flash_forward(q, k, v, causal=causal, window=window,
                            scale=scale, q_offset=q_offset, block_k=block_k)
    return out


def _flash_fwd_rule(q, k, v, causal, window, scale, q_offset, block_k):
    out, lse = _flash_forward(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset, block_k=block_k)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, scale, q_offset, block_k, res, dout):
    """Flash backward: recompute probabilities block-by-block — memory per
    step is O(Sq·block_k); the full S / P matrices are never stacked (the
    naive scan backward saved them per block: 8 GiB/layer at 4k·f32)."""
    q, k, v, out, lse = res
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    nkb = Skv // block_k
    p_dtype = jnp.float32  # see C1 note in _flash_forward
    qf = q.astype(jnp.float32) * scale
    do = dout.astype(jnp.float32).swapaxes(1, 2)          # (B,H,Sq,dh)
    delta = jnp.sum(do * out.astype(jnp.float32).swapaxes(1, 2), -1)  # (B,H,Sq)
    q_pos = q_offset + jnp.arange(Sq)
    kb = k.reshape(B, nkb, block_k, H, dh).swapaxes(0, 1)
    vb = v.reshape(B, nkb, block_k, H, dh).swapaxes(0, 1)

    def step(carry, blk):
        dq, dk_acc, dv_acc = carry
        kt, vt, kb_idx = blk
        k_pos = kb_idx * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kt.astype(jnp.float32))
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)[None, None]
        p = jnp.where(jnp.isinf(s) | jnp.isinf(lse[..., None]), 0.0,
                      jnp.exp(s - lse[..., None]))        # (B,H,Sq,block)
        pb = p.astype(p_dtype)                            # C1: low-p HBM blocks
        dv = jnp.einsum("bhqk,bhqd->bkhd", pb,
                        do.astype(p_dtype)).astype(jnp.float32)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do, vt.astype(jnp.float32))
        ds = (p * (dp - delta[..., None])).astype(p_dtype)  # d(scores)
        dq = dq + scale * jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     kt.astype(p_dtype)).astype(jnp.float32)
        dk = scale * jnp.einsum("bhqk,bqhd->bkhd", ds,
                                q.astype(p_dtype)).astype(jnp.float32)
        # accumulate dk/dv into the carry (dynamic-update-slice): with the
        # query dim sharded, XLA reduces the partial sums ONCE after the
        # scan instead of all-reducing every block (§Perf iteration Q4).
        dk_acc = jax.lax.dynamic_update_slice_in_dim(
            dk_acc, dk, kb_idx * block_k, axis=1)
        dv_acc = jax.lax.dynamic_update_slice_in_dim(
            dv_acc, dv, kb_idx * block_k, axis=1)
        return (dq, dk_acc, dv_acc), None

    dq0 = jnp.zeros((B, Sq, H, dh), jnp.float32)
    dkv0 = jnp.zeros((B, Skv, H, dh), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(
        step, (dq0, dkv0, dkv0), (kb, vb, jnp.arange(nkb)))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def attention_xla(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
                  block_k: int = 512):
    """TPU-adapted QK/SV modules: same tiling idea, online softmax over key
    tiles (running max/sum) so S is never materialised, with a flash-style
    custom VJP (blockwise recompute) so the backward never stacks P either.
    This is what the dry-run lowers and what training uses on non-TPU
    backends."""
    B, Sq, H, dh = q.shape
    Skv = k.shape[1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    k = _broadcast_kv(k, H)
    v = _broadcast_kv(v, H)
    if Skv <= block_k or Skv % block_k:
        return attention_reference(q, k, v, causal=causal, window=window,
                                   scale=scale, q_offset=q_offset)
    return _flash_attention(q, k, v, causal, window, scale, q_offset,
                            block_k)


def attention(q, k, v, *, causal=True, window=0, scale=None, q_offset=0,
              cfg: FamousConfig = FamousConfig()):
    """Dense multi-head attention — FAMOUS QK_PM → softmax → SV_PM."""
    if cfg.impl == "reference":
        return attention_reference(q, k, v, causal=causal, window=window,
                                   scale=scale, q_offset=q_offset)
    if cfg.impl == "pallas":
        # Fully Pallas path (fwd + custom-VJP bwd kernels); tile_q/tile_k are
        # clamped to the sequence lengths inside the wrapper.
        from repro.kernels.attention import ops as attn_ops
        return attn_ops.mha(q, k, v, causal=causal, window=window, scale=scale,
                            q_offset=q_offset, block_q=cfg.tile_q,
                            block_k=cfg.tile_k)
    return attention_xla(q, k, v, causal=causal, window=window, scale=scale,
                         q_offset=q_offset, block_k=cfg.tile_k)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, scale=None,
                     cfg: FamousConfig = FamousConfig()):
    """One-token attention against a KV cache (serving decode step).

    q: (B, 1, H, dh); caches: (B, S_max, KV, dh); cache_len: (B,) int32 —
    number of valid cache entries (the new token's k/v already written).
    """
    B, _, H, dh = q.shape
    Smax = k_cache.shape[1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        return dec_ops.decode_attention(q, k_cache, v_cache, cache_len,
                                        window=window, scale=scale,
                                        block_k=cfg.tile_k)
    k = _broadcast_kv(k_cache, H)
    v = _broadcast_kv(v_cache, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    pos = jnp.arange(Smax)[None, :]                      # (1, Smax)
    ok = pos < cache_len[:, None]
    if window:
        ok &= pos > (cache_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def verify_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     cfg: FamousConfig = FamousConfig()):
    """Speculative-verify attention against a contiguous KV cache.

    q: (B, W, H, dh) — the W verify tokens of each slot at absolute
    positions ``cache_len[b] + j`` (their K/V already written); caches:
    (B, S_max, KV, dh); cache_len: (B,) int32.  Query j attends keys at
    positions ``<= cache_len[b] + j`` — W == 1 is exactly
    :func:`decode_attention`, so a zero-draft slot verifies as a plain
    decode.  The per-slot offsets are runtime operands: one executable
    serves every draft-length mix (``W`` is the engine's static
    ``draft_k + 1`` cap; short drafts ride as masked pad rows).

    impl="pallas" flattens each (slot, verify position) pair into a row of
    the decode kernel (per-row lengths — see kernels/decode/ops.py);
    other impls run the dense masked oracle below.
    """
    B, W, H, dh = q.shape
    Smax = k_cache.shape[1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        return dec_ops.verify_attention(q, k_cache, v_cache, cache_len,
                                        scale=scale, block_k=cfg.tile_k)
    k = _broadcast_kv(k_cache, H)
    v = _broadcast_kv(v_cache, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = cache_len[:, None] + jnp.arange(W)[None, :]         # (B, W)
    ok = jnp.arange(Smax)[None, None, :] <= q_pos[:, :, None]   # (B, W, Smax)
    s = jnp.where(ok[:, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_verify_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           scale=None, k_scale=None, v_scale=None,
                           cfg: FamousConfig = FamousConfig()):
    """Speculative-verify attention against a *paged* KV cache.

    q: (B, W, H, dh) at per-slot positions ``cache_len[b] + j``; pools:
    (n_pages, page_size, KV, dh); page_table: (B, n_p) int32.  impl=
    "pallas" flattens (slot, verify position) pairs into rows of the
    scalar-prefetched page-table decode kernel; other impls gather the
    table into a contiguous view and reuse :func:`verify_attention`.

    With ``k_scale``/``v_scale`` (fp32 (n_pages, page_size, KV) pools) the
    K/V pools are int8 and dequantized in-kernel (pallas) or via the
    dequantizing gather (other impls) — the ``kv_dtype="int8"`` path.
    """
    dh = q.shape[-1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        if k_scale is not None:
            return dec_ops.paged_verify_attention_int8(
                q, k_pages, v_pages, k_scale, v_scale, page_table,
                cache_len, scale=scale)
        return dec_ops.paged_verify_attention(q, k_pages, v_pages,
                                              page_table, cache_len,
                                              scale=scale)
    from repro.kernels.decode.ref import gather_pages, gather_pages_int8
    if k_scale is not None:
        k = gather_pages_int8(k_pages, k_scale, page_table)
        v = gather_pages_int8(v_pages, v_scale, page_table)
    else:
        k = gather_pages(k_pages, page_table)
        v = gather_pages(v_pages, page_table)
    return verify_attention(q, k, v, cache_len, scale=scale, cfg=cfg)


def attention_at_positions(q, k, v, q_pos, k_pos, *, window=0, scale=None):
    """Dense masked attention with *explicit* absolute positions.

    q: (B, Sq, H, dh) at positions ``q_pos`` (Sq,); k/v: (B, Skv, KV, dh) at
    positions ``k_pos`` (Skv,).  Causal: query i sees key j iff
    ``k_pos[j] <= q_pos[i]`` (and within ``window`` when set); negative
    ``k_pos`` entries mark invalid keys (e.g. ring-buffer slots not yet
    written) and are always masked.  XLA-only helper for the ring-buffer
    chunked-prefill path, where keys are a gathered window rather than a
    cache prefix.
    """
    B, Sq, H, dh = q.shape
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    k = _broadcast_kv(k, H)
    v = _broadcast_kv(v, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    ok = (k_pos[None, :] <= q_pos[:, None]) & (k_pos >= 0)[None, :]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def chunked_prefill_attention(q, k_cache, v_cache, q_offset, *, scale=None,
                              cfg: FamousConfig = FamousConfig()):
    """Chunked-prefill attention: a chunk of C query tokens at absolute
    positions ``[q_offset, q_offset + C)`` attends causally to the resident
    prefix *plus its own chunk*, both already written into the cache.

    q: (B, C, H, dh); caches: (B, S_max, KV, dh) with the chunk's K/V rows
    already written at ``[q_offset, q_offset + C)``.  ``q_offset`` is a
    runtime scalar — one executable serves every (prompt length, chunk
    index) pair, the paper's "reprogram loop bounds, never re-synthesise"
    applied to prefill.  impl="pallas" streams key tiles through the
    online-softmax kernel in kernels/decode; other impls run the dense
    masked reference (the parity oracle).
    """
    B, C, H, dh = q.shape
    Skv = k_cache.shape[1]
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        return dec_ops.chunk_prefill_attention(q, k_cache, v_cache, q_offset,
                                               scale=scale, block_k=cfg.tile_k)
    k = _broadcast_kv(k_cache, H)
    v = _broadcast_kv(v_cache, H)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(C)
    ok = jnp.arange(Skv)[None, :] <= q_pos[:, None]
    s = jnp.where(ok[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_chunked_prefill_attention(q, k_pages, v_pages, page_table, q_offset,
                                    *, scale=None, k_scale=None, v_scale=None,
                                    cfg: FamousConfig = FamousConfig()):
    """Chunked-prefill attention against a *paged* KV cache.

    q: (B, C, H, dh) at positions ``[q_offset, q_offset + C)``; pools:
    (n_pages, page_size, KV, dh); page_table: (B, n_p) int32.  The chunk's
    K/V must already be scattered into the slot's pages.  impl="pallas"
    reuses the scalar-prefetched page-table BlockSpec machinery of
    ``paged_decode_attention``; other impls gather the table into a
    contiguous view and run the dense chunked reference.  ``k_scale``/
    ``v_scale`` select the int8-pool path (see paged_verify_attention).
    """
    B, C, H, dh = q.shape
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        if k_scale is not None:
            return dec_ops.paged_chunk_prefill_attention_int8(
                q, k_pages, v_pages, k_scale, v_scale, page_table,
                q_offset, scale=scale)
        return dec_ops.paged_chunk_prefill_attention(q, k_pages, v_pages,
                                                     page_table, q_offset,
                                                     scale=scale)
    from repro.kernels.decode.ref import gather_pages, gather_pages_int8
    if k_scale is not None:
        k = gather_pages_int8(k_pages, k_scale, page_table)
        v = gather_pages_int8(v_pages, v_scale, page_table)
    else:
        k = gather_pages(k_pages, page_table)
        v = gather_pages(v_pages, page_table)
    return chunked_prefill_attention(q, k, v, q_offset, scale=scale, cfg=cfg)


def paged_decode_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           scale=None, k_scale=None, v_scale=None,
                           cfg: FamousConfig = FamousConfig()):
    """One-token attention against a *paged* KV cache.

    q: (B, 1, H, dh); pools: (n_pages, page_size, KV, dh) shared by every
    sequence; page_table: (B, n_p) int32 page ids per slot; cache_len: (B,)
    int32 valid entries (the new token's k/v already written to its page).

    impl="pallas" streams pages directly via a scalar-prefetched page table
    (kernels/decode); other impls gather the table into a contiguous
    per-slot view and reuse the dense decode path — the XLA reference the
    kernel is validated against.  ``k_scale``/``v_scale`` select the int8
    pool path (see paged_verify_attention).
    """
    B, _, H, dh = q.shape
    scale = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    if cfg.impl == "pallas":
        from repro.kernels.decode import ops as dec_ops
        if k_scale is not None:
            return dec_ops.paged_decode_attention_int8(
                q, k_pages, v_pages, k_scale, v_scale, page_table,
                cache_len, scale=scale)
        return dec_ops.paged_decode_attention(q, k_pages, v_pages,
                                              page_table, cache_len,
                                              scale=scale)
    from repro.kernels.decode.ref import gather_pages, gather_pages_int8
    if k_scale is not None:
        k = gather_pages_int8(k_pages, k_scale, page_table)
        v = gather_pages_int8(v_pages, v_scale, page_table)
    else:
        k = gather_pages(k_pages, page_table)
        v = gather_pages(v_pages, page_table)
    return decode_attention(q, k, v, cache_len, scale=scale, cfg=cfg)


# ---------------------------------------------------------------------------
# Full MHA layer (projection + attention + output) — the paper's fig. 3 box.
# ---------------------------------------------------------------------------

def mha_block(x, params, *, num_heads, num_kv_heads, causal=True, window=0,
              qk_norm_fn=None, cfg: FamousConfig = FamousConfig(),
              rope_fn=None, q_offset=0):
    """x: (B, S, D).  params: dict with wq/wk/wv (D,H,dh), optional b*,
    wo (H, dh, D).  Returns (B, S, D)."""
    q, k, v = qkv_projection(
        x, params["wq"], params["wk"], params["wv"],
        params.get("bq"), params.get("bk"), params.get("bv"), cfg=cfg)
    if qk_norm_fn is not None:
        q, k = qk_norm_fn(q, k)
    if rope_fn is not None:
        q, k = rope_fn(q, k)
    out = attention(q, k, v, causal=causal, window=window, q_offset=q_offset,
                    cfg=cfg)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"].astype(out.dtype))

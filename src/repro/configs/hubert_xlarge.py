"""hubert-xlarge — encoder-only audio transformer (wav2vec2 architecture).

[arXiv:2106.07447; unverified]
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (codebook targets).

Encoder-only: bidirectional attention, no decode step (decode shapes are
skipped, see DESIGN.md §6).  The CNN waveform frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        rope=False,
        norm="layernorm",
        act="gelu",
        frontend="audio",
        source="arXiv:2106.07447; unverified",
    )
)

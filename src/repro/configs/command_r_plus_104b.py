"""command-r-plus-104b — large dense GQA decoder, no biases.

[hf:CohereForAI/c4ai-command-r-v01; unverified]
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        tie_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
)

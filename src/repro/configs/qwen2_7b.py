"""qwen2-7b — dense GQA decoder with QKV bias.

[arXiv:2407.10671; hf]  28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        attention_bias=True,
        rope_theta=1e6,
        source="arXiv:2407.10671; hf",
    )
)

"""Model/run configuration system.

Every assigned architecture is a :class:`ModelConfig` registered under its id
(``--arch <id>``).  Shapes (``--shape <id>``) are :class:`ShapeConfig`.  A
``RunConfig`` bundles (arch, shape, mesh, parallelism/runtime knobs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Layer kinds used by the generic block stack.
ATTN = "attn"            # global dense softmax attention (FAMOUS applies)
LOCAL_ATTN = "local_attn"  # sliding-window attention (FAMOUS + window mask)
RGLRU = "rglru"          # Griffin/RecurrentGemma recurrent block
RWKV6 = "rwkv6"          # RWKV-6 "Finch" time-mix block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0               # 0 -> d_model // num_heads
    # Block stack: ``pattern_unit`` repeated ``num_layers // len(unit)`` times
    # via lax.scan, plus an explicit tail of ``num_layers % len(unit)`` layers.
    pattern_unit: tuple[str, ...] = (ATTN,)
    # Attention details ------------------------------------------------------
    causal: bool = True             # False for encoder-only (hubert)
    attention_bias: bool = False    # qwen2-style QKV bias (paper: B_q/B_k/B_v)
    qk_norm: bool = False           # qwen3-style per-head RMSNorm on q,k
    window: int = 0                 # local-attention window (0 = global)
    rope: bool = True
    rope_theta: float = 10000.0
    logit_softcap: float = 0.0
    # MoE ---------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / recurrence --------------------------------------------------------
    lru_width: int = 0              # RG-LRU state width (0 -> d_model)
    conv_width: int = 4             # temporal conv in the recurrent block
    rwkv_head_dim: int = 64
    # Misc --------------------------------------------------------------------
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # silu | gelu | relu_sq
    tie_embeddings: bool = False
    frontend: Optional[str] = None  # None | "audio" | "vlm" (stub embeddings)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.num_layers % len(self.pattern_unit) in range(len(self.pattern_unit))

    # ---- derived ----------------------------------------------------------
    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_attention_free(self) -> bool:
        return all(k in (RGLRU, RWKV6) for k in self.pattern_unit)

    @property
    def is_subquadratic(self) -> bool:
        """True if no *global* dense attention layer exists (long_500k ok)."""
        return all(k != ATTN for k in self.pattern_unit)

    @property
    def num_units(self) -> int:
        return self.num_layers // len(self.pattern_unit)

    @property
    def tail_layers(self) -> tuple[str, ...]:
        n_tail = self.num_layers % len(self.pattern_unit)
        return self.pattern_unit[:n_tail]

    def param_count(self) -> int:
        """Total parameters (analytic, matches init)."""
        from repro.models.transformer import model_spec
        from repro.models.module import count_params

        return count_params(model_spec(self))

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        total = self.param_count()
        if self.num_experts == 0:
            return total
        d_ff, e, k = self.d_ff, self.num_experts, self.experts_per_token
        per_expert = 3 * self.d_model * d_ff
        inactive = self.num_layers * per_expert * (e - k)  # every block is MoE
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# smoke/test shapes (reduced)
SMOKE_SHAPES: dict[str, ShapeConfig] = {
    "smoke_train": ShapeConfig("smoke_train", 32, 2, "train"),
    "smoke_decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
}

_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import all config modules for registration side effects
    from repro.configs import (  # noqa: F401
        recurrentgemma_2b,
        qwen2_7b,
        qwen3_32b,
        deepseek_7b,
        command_r_plus_104b,
        llava_next_34b,
        grok_1_314b,
        kimi_k2_1t_a32b,
        hubert_xlarge,
        rwkv6_1b6,
        famous_bert,
    )


def supported_cells(arch: str) -> list[str]:
    """Which of the four assigned shapes are well-defined for this arch."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder_only:
        cells.append("decode_32k")
        if cfg.is_subquadratic:
            cells.append("long_500k")
    return cells


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Bundle of (arch, shape, parallelism) for a serving/dry-run launch.

    ``tp`` is tensor parallelism on the "model" mesh axis (attention heads /
    kv heads / FFN hidden — see ``parallel.sharding.SERVE_TP_RULES``), ``dp``
    replica groups on "data".  ``tp == dp == 1`` means no mesh at all: the
    engine runs its unsharded single-device baseline.
    """
    arch: str
    shape: str = "smoke_decode"
    tp: int = 1
    dp: int = 1

    def __post_init__(self):
        assert self.tp >= 1 and self.dp >= 1, (self.tp, self.dp)

    @property
    def mesh_shape(self) -> tuple[int, int]:
        return (self.dp, self.tp)

    @property
    def needs_mesh(self) -> bool:
        return self.tp > 1 or self.dp > 1

    def make_mesh(self):
        """Build the (dp, tp) serving mesh, or None when unsharded."""
        if not self.needs_mesh:
            return None
        from repro.launch.mesh import make_serving_mesh
        return make_serving_mesh(tp=self.tp, dp=self.dp)


def shrink(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    unit = cfg.pattern_unit
    defaults = dict(
        name=cfg.name + "-smoke",
        num_layers=2 * len(unit) + len(cfg.tail_layers),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        lru_width=64 if cfg.lru_width else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        rwkv_head_dim=16,
    )
    defaults.update(over)
    return dataclasses.replace(cfg, **defaults)

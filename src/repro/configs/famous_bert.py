"""famous-bert — the paper's own evaluation topology.

FAMOUS (Table I) synthesises for a BERT variant: d_model=768, h=8, SL=64,
TS=64, 8-bit data.  This config reproduces that topology as an encoder so the
paper's Table I/II sweeps can be run verbatim by the benchmark harness.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="famous-bert",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=8,
        num_kv_heads=8,
        d_ff=3072,
        vocab_size=30522,
        causal=False,
        rope=False,
        norm="layernorm",
        act="gelu",
        source="FAMOUS paper Table I (BERT variant [6])",
    )
)

"""rwkv6-1.6b "Finch" — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; unverified]
24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.

FAMOUS's attention tiling is inapplicable (no softmax attention); the block is
the wkv6 linear recurrence (chunked kernel, see kernels/scan).  Noted in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import RWKV6, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,           # wkv heads = d_model / rwkv_head_dim
        num_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        pattern_unit=(RWKV6,),
        rwkv_head_dim=64,
        rope=False,
        norm="layernorm",
        act="relu_sq",
        source="arXiv:2404.05892; unverified",
    )
)

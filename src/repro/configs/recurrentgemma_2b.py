"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
Pattern: (rglru, rglru, local_attn) repeated; 26 % 3 = 2 trailing rglru layers.
Local attention window 2048 (Griffin), GeLU MLP, RMSNorm.
"""
from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        head_dim=256,
        pattern_unit=(RGLRU, RGLRU, LOCAL_ATTN),
        window=2048,
        lru_width=2560,
        conv_width=4,
        act="gelu",
        rope=True,
        tie_embeddings=True,
        source="arXiv:2402.19427; hf",
    )
)

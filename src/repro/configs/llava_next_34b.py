"""llava-next-34b — VLM: decoder LM backbone + anyres patch-embedding stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Per the assignment, the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (the anyres tiler output) alongside text tokens;
the backbone consumes the concatenated embedding sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="llava-next-34b",
        family="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        frontend="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
    )
)

"""While-loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body **once**, so any
scanned model (layers via lax.scan, flash key-block loops, loss chunking,
microbatching) is undercounted by the trip count — 35× for a 48-layer stack.
This module re-derives FLOPs / HBM bytes / collective bytes from the
optimized HLO *with call-graph multiplicities*:

  * computations are parsed into instruction lists;
  * ``while`` ops contribute ``known_trip_count`` (XLA annotates scans; a
    condition-constant fallback covers the rest) to their body/condition;
  * ``fusion``/``call``/``conditional`` propagate multiplicity 1;
  * FLOPs: 2·|result|·|contraction| summed over ``dot`` ops in every
    computation, scaled by the computation's multiplicity;
  * bytes: per *executable* computation (entry / while bodies — fusion
    internals are on-chip and do not touch HBM), each top-level instruction
    contributes result + operand bytes, skipping parameters / GTEs / tuples /
    constants / bitcasts (no data movement);
  * collective bytes: as roofline.analysis, but scaled by multiplicity.

Validated against analytic per-layer counts in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(\(.*\))\s*->")
# the result type may be a tuple containing `/*index=N*/` comments (which
# include '='), so match it lazily up to the first " op(" boundary.
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_SKIP_BYTES = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "iota", "after-all", "partition-id", "replica-id",
               # loop carries alias in place; their bodies' writes are
               # already charged inside the body computation
               "while", "conditional", "optimization-barrier"}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions: 0.4.x
    returns a one-element list of dicts, 0.5+ the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str       # everything after the opening paren of operands
    line: str
    root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: dict            # param name -> type str
    instrs: list
    is_entry: bool = False

    def shapes(self) -> dict:
        out = dict(self.params)
        for i in self.instrs:
            out[i.name] = i.type_str
        return out


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or closing brace
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                params = {}
                for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*([^,)]+)",
                                      m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), params, [],
                                  is_entry=line.lstrip().startswith("ENTRY"))
                comps[cur.name] = cur
            elif line.strip() == "}":
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2).strip(),
                                    m.group(3), m.group(4), line,
                                    root="ROOT" in line.split("=")[0]))
    return comps


_CALL_ATTRS = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_TRIP = re.compile(r'known_trip_count[^\d]*(\d+)')


def _trip_count(instr: Instr, comps: dict) -> int:
    m = _TRIP.search(instr.line)
    if m:
        return int(m.group(1))
    # fallback: largest s32 constant in the condition computation
    cm = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if cm and cm.group(1) in comps:
        best = 1
        for i in comps[cm.group(1)].instrs:
            c = re.match(r"s32\[\]", i.type_str)
            k = re.search(r"constant\((\d+)\)", i.line)
            if c and k:
                best = max(best, int(k.group(1)))
        return best
    return 1


def multiplicities(comps: dict[str, Computation]) -> dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return {name: 1.0 for name in comps}
    seen_stack: set = set()

    def visit(name: str, m: float):
        if name not in comps or m <= 0 or name in seen_stack:
            return
        mult[name] += m
        seen_stack.add(name)
        comp = comps[name]
        for i in comp.instrs:
            if i.op == "while":
                trips = _trip_count(i, comps)
                for attr in ("body", "condition"):
                    am = re.search(attr + r"=%?([\w.\-]+)", i.line)
                    if am:
                        visit(am.group(1), m * trips)
            else:
                am = _CALL_ATTRS.search(i.line)
                if am:
                    for callee in re.split(r",\s*", am.group(1)):
                        visit(callee.lstrip("%"), m)
        seen_stack.discard(name)

    visit(entry.name, 1.0)
    return dict(mult)


def _dot_flops(instr: Instr, shapes: dict) -> float:
    out_elems = 1
    for d in _dims(instr.type_str):
        out_elems *= d
    # contraction size from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    seg = instr.rest.split(")")[0]
    # newer XLA dumps inline the operand type (`dot(f32[a,b]{1,0} %x, ...)`)
    # — read the lhs shape straight off the first operand when present;
    # otherwise resolve the operand name against the computation's shapes.
    inline = re.match(r"\s*(\w+\[[0-9,]*\])", seg)
    if inline:
        ldims = _dims(inline.group(1))
    else:
        ops = [o.strip().lstrip("%") for o in
               re.split(r",\s*(?![^{]*\})", seg) if o.strip()]
        ldims = _dims(shapes.get(ops[0], "")) if ops else []
    contract = 1
    if m:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(ldims):
                contract *= ldims[int(idx)]
    return 2.0 * out_elems * contract


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 1


def _operands(instr: Instr) -> list[str]:
    # operand list: names at the start of `rest` until the closing paren
    depth = 1
    buf = []
    for ch in instr.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return re.findall(r"%([\w.\-]+)", "".join(buf))


def _operand_bytes(instr: Instr, shapes: dict) -> int:
    return sum(_type_bytes(shapes.get(n, "")) for n in _operands(instr))


def _write_bytes(instr: Instr, shapes: dict, comps: dict) -> int:
    """Effective HBM bytes *written* by one top-level instruction.

    dynamic-update-slice is in-place: only the update slice is written —
    counting the full result would charge the whole scan-carry buffer (e.g.
    a 16 GiB remat stack) on every loop iteration.  DUS-rooted fusions get
    the same treatment via their computation's root.
    """
    if instr.op == "dynamic-update-slice":
        ops = _operands(instr)
        if len(ops) >= 2:
            return _type_bytes(shapes.get(ops[1], ""))
    if instr.op == "fusion":
        am = re.search(r"calls=%?([\w.\-]+)", instr.line)
        if am and am.group(1) in comps:
            fc = comps[am.group(1)]
            root = next((i for i in fc.instrs if i.root), None)
            if root is not None and root.op == "dynamic-update-slice":
                fshapes = fc.shapes()
                ops = _operands(root)
                if len(ops) >= 2:
                    return _type_bytes(fshapes.get(ops[1], ""))
    return _type_bytes(instr.type_str)


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    coll_moved: float
    coll_by_type: dict
    coll_counts: dict
    while_trips: dict


def analyse_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    mult = multiplicities(comps)
    # executable contexts: entry + while bodies/conds (things with mult that
    # are not pure fusion callees).  Fusion computations never contain
    # collectives and their internals don't touch HBM.
    fusion_callees: set = set()
    while_comps: set = set()
    trips: dict = {}
    for c in comps.values():
        for i in c.instrs:
            if i.op == "fusion":
                am = re.search(r"calls=%?([\w.\-]+)", i.line)
                if am:
                    fusion_callees.add(am.group(1))
            if i.op == "while":
                t = _trip_count(i, comps)
                for attr in ("body", "condition"):
                    am = re.search(attr + r"=%?([\w.\-]+)", i.line)
                    if am:
                        while_comps.add(am.group(1))
                        trips[am.group(1)] = t

    flops = 0.0
    bytes_acc = 0.0
    coll = defaultdict(float)
    counts = defaultdict(int)
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        shapes = comp.shapes()
        executable = comp.is_entry or name in while_comps
        for i in comp.instrs:
            if i.op == "dot":
                flops += m * _dot_flops(i, shapes)
            if not executable:
                continue
            base = i.op.replace("-start", "").replace("-done", "")
            if base in _COLL_OPS and not i.op.endswith("-done"):
                b = _type_bytes(i.type_str)
                n = _group_size(i.line)
                if base == "all-reduce":
                    moved = 2.0 * b * max(n - 1, 0) / max(n, 1)
                elif base == "all-gather":
                    moved = 1.0 * b * max(n - 1, 0) / max(n, 1)
                elif base == "reduce-scatter":
                    moved = float(b) * max(n - 1, 0)
                else:
                    moved = float(b)
                coll[base] += m * moved
                counts[base] += 1
            if i.op in _SKIP_BYTES or i.op.endswith("-done"):
                continue
            # read+write model: each materialised buffer is written once and
            # read ~once; DUS-adjusted (see _write_bytes).  Validated within
            # ~2x of analytic per-layer traffic in tests/test_roofline.py.
            bytes_acc += m * 2 * _write_bytes(i, shapes, comps)
    return HloCost(
        flops=flops,
        bytes_accessed=bytes_acc,
        coll_moved=sum(coll.values()),
        coll_by_type=dict(coll),
        coll_counts=dict(counts),
        while_trips=trips,
    )

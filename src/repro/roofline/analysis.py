"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms per (arch × shape × mesh), all in seconds *per device* (XLA SPMD
modules are per-device programs, so ``cost_analysis()`` FLOPs/bytes are
already per-chip — equivalent to the total/chips formulation):

    compute    = HLO_FLOPs        / peak_FLOP/s
    memory     = HLO_bytes        / HBM_bw
    collective = collective_bytes / ICI_bw

``collective_bytes`` is parsed from the optimized HLO: result shapes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, converted to *moved* bytes with a ring-algorithm model:

    all-reduce      2 × bytes     (reduce-scatter + all-gather phases)
    all-gather      1 × result    (each chip receives (N−1)/N ≈ 1 of result)
    reduce-scatter  N × result    (input = N × result crosses the ring once)
    all-to-all      1 × bytes
    collective-permute 1 × bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.core.analytical import TpuSpec, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor shape in a (possibly tuple) type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type moved bytes (per device) from HLO text."""
    out: dict = defaultdict(float)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # strip -start/-done fusion suffixes (async collectives)
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLL_OPS:
            continue
        if op.endswith("-done"):
            counts[base + "_done"] += 1
            continue  # avoid double count with its -start
        b = _shape_bytes(type_str)
        n = _group_size(line)
        if base == "all-reduce":
            moved = 2.0 * b * max(n - 1, 0) / max(n, 1)
        elif base == "all-gather":
            moved = 1.0 * b * max(n - 1, 0) / max(n, 1)
        elif base == "reduce-scatter":
            moved = float(b) * max(n - 1, 0)
        else:
            moved = float(b)
        out[base] += moved
        out["raw_" + base] += b
        counts[base] += 1
    out["total_moved"] = sum(out[k] for k in _COLL_OPS if k in out)
    out["counts"] = dict(counts)
    return dict(out)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops: float            # per device
    bytes_accessed: float   # per device
    coll_bytes: float       # per device, moved
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_total: float
    chips: int
    coll_detail: dict
    memory_per_device: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        hlo_total = self.flops * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the *useful* model FLOPs achieve when
        running at the bound: (model_flops/chips/peak) / t_bound."""
        if self.t_bound <= 0:
            return 0.0
        t_useful = self.model_flops_total / self.chips / V5E.peak_bf16
        return t_useful / self.t_bound

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_per_device_gib": self.memory_per_device / 2**30,
            "collectives": self.coll_detail.get("counts", {}),
        }


def analyse(arch: str, shape: str, mesh_name: str, *, cost: dict,
            hlo_text: str, chips: int, model_flops_total: float,
            memory_per_device: float = 0.0, hw: TpuSpec = V5E) -> Roofline:
    """Roofline terms from the compiled HLO.

    ``cost`` (XLA's cost_analysis) undercounts while-loop bodies (trip count
    not applied), so FLOPs/bytes come from the while-aware HLO text model
    (roofline/hlo_cost.py); the raw cost_analysis numbers are kept in the
    dry-run JSON for reference.
    """
    from repro.roofline import hlo_cost

    hc = hlo_cost.analyse_hlo(hlo_text)
    flops = hc.flops
    by = hc.bytes_accessed
    moved = hc.coll_moved
    coll = dict(hc.coll_by_type)
    coll["counts"] = hc.coll_counts
    coll["total_moved"] = moved
    coll["while_trips"] = hc.while_trips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops=flops, bytes_accessed=by, coll_bytes=moved,
        t_compute=flops / hw.peak_bf16,
        t_memory=by / hw.hbm_bw,
        t_collective=moved / hw.ici_bw,
        model_flops_total=model_flops_total,
        chips=chips, coll_detail=coll,
        memory_per_device=memory_per_device,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D train (N = active params for
    MoE), 2·N·D prefill, 2·N·B decode (one token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
